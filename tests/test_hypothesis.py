"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.phantom import (phantom_dense_equivalent, phantom_decls,
                                phantom_param_count)
from repro.models.moe import moe_capacity, route
from repro.parallel.params import materialize, param_count

SET = dict(max_examples=20, deadline=None)


@given(p=st.sampled_from([2, 4, 8]),
       bi=st.sampled_from([2, 4, 8]),
       bo=st.sampled_from([2, 4, 8]),
       k=st.integers(1, 4),
       seed=st.integers(0, 10_000))
@settings(**SET)
def test_phantom_dense_equivalent_block_structure(p, bi, bo, k, seed):
    """The dense-equivalent matrix has EXACT diagonal blocks and rank<=k
    off-diagonal blocks — the paper's Fig. 2/4 structure, for any
    geometry."""
    from repro.parallel.axes import MeshAxes
    decls = phantom_decls(p * bi, p * bo, k, p)
    params = materialize(decls, seed)
    W = np.asarray(phantom_dense_equivalent(params))
    L = np.asarray(params["L"])
    for i in range(p):
        for jj in range(p):
            blk = W[i * bi:(i + 1) * bi, jj * bo:(jj + 1) * bo]
            if i == jj:
                np.testing.assert_allclose(blk, L[i], rtol=1e-6)
            else:
                assert np.linalg.matrix_rank(blk, tol=1e-5) <= k


@given(p=st.sampled_from([2, 4, 8, 16]),
       n=st.sampled_from([64, 128, 256]),
       k=st.integers(1, 8))
@settings(**SET)
def test_phantom_param_count_matches_decls(p, n, k):
    decls = phantom_decls(n, n, k, p)
    assert param_count(decls) == phantom_param_count(n, n, k, p)


@given(T=st.sampled_from([16, 64, 256]),
       E=st.sampled_from([4, 8, 16]),
       K=st.integers(1, 4),
       seed=st.integers(0, 1000))
@settings(**SET)
def test_route_invariants(T, E, K, seed):
    """Dispatch invariants for any routing input: capacity respected,
    tokens valid, gates normalized, kept slots bijective."""
    K = min(K, E)
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((T, E)), jnp.float32)
    C = moe_capacity(T, E, K, 1.25)
    disp_tok, disp_ok, gates, combine_slot = route(logits, K, C)
    assert disp_tok.shape == (E, C) and disp_ok.shape == (E, C)
    assert np.asarray(disp_tok).min() >= 0
    assert np.asarray(disp_tok).max() < T
    g = np.asarray(gates)
    np.testing.assert_allclose(g.sum(-1), 1.0, rtol=1e-4)
    slots = np.asarray(combine_slot)
    kept = slots[slots >= 0]
    assert len(np.unique(kept)) == len(kept)          # bijective slots
    assert (kept < E * C).all()
    # count consistency: #kept slot ids == #ok dispatch entries
    assert len(kept) == int(np.asarray(disp_ok).sum())


@given(shape=st.sampled_from([(4,), (3, 5), (2, 3, 4)]),
       seed=st.integers(0, 100))
@settings(**SET)
def test_checkpoint_roundtrip_arbitrary_pytrees(tmp_path_factory, shape,
                                                seed):
    from repro.train.checkpoint import CheckpointManager
    from repro.parallel.params import ParamDecl
    from jax.sharding import PartitionSpec as P
    rng = np.random.default_rng(seed)
    tmp = tmp_path_factory.mktemp(f"ck{seed}")
    params = {"a": jnp.asarray(rng.standard_normal(shape), jnp.float32),
              "nested": {"b": jnp.asarray(rng.integers(0, 5, shape),
                                          jnp.int32)}}
    decls = jax.tree.map(lambda x: ParamDecl(x.shape, P(),
                                             dtype=x.dtype), params)
    mgr = CheckpointManager(str(tmp))
    mgr.save(1, params, {})
    state = mgr.restore(1, decls, {}, None)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(S=st.sampled_from([8, 16, 32]),
       H=st.sampled_from([2, 4]),
       seed=st.integers(0, 500))
@settings(**SET)
def test_ssd_chunk_invariance_property(S, H, seed):
    from repro.models.ssm import _ssd_chunked
    rng = np.random.default_rng(seed)
    B, hd, N = 2, 4, 8
    x = jnp.asarray(rng.standard_normal((B, S, H, hd)) * 0.5, jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((B, S, H)),
                                     jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.standard_normal(H) * 0.3, jnp.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    y1, s1 = _ssd_chunked(x, dt, A, Bm, Cm, 4)
    y2, s2 = _ssd_chunked(x, dt, A, Bm, Cm, S)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                               atol=2e-3)


@given(m=st.sampled_from([64, 1024, 65536]),
       p=st.sampled_from([2, 16, 256]))
@settings(**SET)
def test_comm_model_monotone(m, p):
    """Paper Eqn. 26 comm model: monotone in message size and ranks."""
    from repro.core.energy import comm_time_us
    for coll in ("all_gather", "reduce_scatter", "all_reduce", "broadcast"):
        assert comm_time_us(coll, 2 * m, p) > comm_time_us(coll, m, p)
        assert comm_time_us(coll, m, 2 * p) > comm_time_us(coll, m, p)
    # p2p (stage boundary): monotone in m, a SINGLE hop in p
    assert comm_time_us("collective_permute", 2 * m, p) \
        > comm_time_us("collective_permute", m, p)
    assert comm_time_us("collective_permute", m, 2 * p) \
        == comm_time_us("collective_permute", m, p)


# ---------------------------------------------------------------------------
# pipeline parallelism: the 1F1B schedule and the SPMD wavefront
# ---------------------------------------------------------------------------

@given(S=st.integers(1, 6), M=st.integers(1, 12))
@settings(**SET)
def test_1f1b_schedule_invariants(S, M):
    """For any geometry: every microbatch runs exactly one F and one B
    per stage, B_i only after F_i, the warmup depth and the 1F1B
    in-flight bound hold, and the wavefront geometry is consistent."""
    from repro.train.pipeline import PipelineSchedule
    sched = PipelineSchedule(stages=S, microbatches=M)
    assert sched.num_ticks == M + S - 1
    assert 0.0 <= sched.bubble_fraction < 1.0
    for s in range(S):
        ops = sched.table(s)
        fwd = [m for op, m in ops if op == "F"]
        bwd = [m for op, m in ops if op == "B"]
        assert fwd == list(range(M)) and bwd == list(range(M))
        done_f, in_flight, peak = set(), 0, 0
        for op, m in ops:
            if op == "F":
                done_f.add(m)
                in_flight += 1
            else:
                assert m in done_f         # backward needs its forward
                in_flight -= 1
            peak = max(peak, in_flight)
        assert peak == sched.max_in_flight(s) == min(M, S - s)
        assert ops[:sched.warmup(s)] == [("F", i)
                                         for i in range(sched.warmup(s))]
    ideal = sched.p2p_events(100.0)
    spmd = sched.p2p_events(100.0, executed=True)
    if S == 1:
        assert ideal == spmd == []
    else:
        assert len(ideal) == 2 * M
        assert len(spmd) == 2 * (M + S - 2)
        assert all(ev.collective == "collective_permute" for ev in spmd)


@given(kind=st.sampled_from(["tensor", "phantom", "mixed"]),
       k=st.sampled_from([2, 4]),
       M=st.sampled_from([1, 2, 4]),
       pp=st.sampled_from([2, 4]),
       seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_pipeline_1f1b_equivalence(mesh222, mesh124, mesh12,
                                   compiled_step_cache, kind, k, M, pp,
                                   seed):
    """THE pipeline correctness pin: for hypothesis-drawn (strategy
    kind, ghost width, microbatches, stages, seed), the 1F1B wavefront
    on a pp mesh produces the SAME loss and gradients (params and
    input) as the sequential single-stage reference on a pp=1 mesh,
    within float-reassociation tolerance — for tensor, phantom, and
    mixed per-stage strategies.  (``helpers.assert_pipeline_equivalence``
    is the shared oracle; test_pipeline.py pins fixed cases.)"""
    from helpers import assert_pipeline_equivalence
    if kind == "tensor":
        k = 2                      # dead knob for tensor: dedupe compiles
    mesh_pp = mesh222 if pp == 2 else mesh124
    assert_pipeline_equivalence(compiled_step_cache, mesh_pp, mesh12,
                                kind, k, M, pp, seed)
