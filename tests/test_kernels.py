"""Pallas kernel validation (interpret=True on CPU; TPU is the target):
shape/dtype sweep against the pure-jnp oracle in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.phantom_fused import phantom_fused_matmul
from repro.kernels.ref import phantom_fused_ref
from helpers import allclose, rand


@pytest.mark.parametrize("M,K,N,PK", [
    (128, 128, 128, 64),
    (256, 128, 128, 128),
    (128, 256, 384, 32),
    (512, 128, 256, 256),
    (128, 512, 128, 16),
])
def test_phantom_fused_shapes(M, K, N, PK):
    x = rand(0, (M, K), scale=0.3)
    L = rand(1, (K, N), scale=0.3)
    g = rand(2, (M, PK), scale=0.3)
    D = rand(3, (PK, N), scale=0.3)
    out = phantom_fused_matmul(x, L, g, D, interpret=True)
    ref = phantom_fused_ref(x, L, g, D)
    allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_phantom_fused_dtypes(dtype):
    M, K, N, PK = 128, 128, 128, 64
    x = rand(4, (M, K), scale=0.3).astype(dtype)
    L = rand(5, (K, N), scale=0.3).astype(dtype)
    g = rand(6, (M, PK), scale=0.3).astype(dtype)
    D = rand(7, (PK, N), scale=0.3).astype(dtype)
    out = phantom_fused_matmul(x, L, g, D, interpret=True)
    ref = phantom_fused_ref(x, L, g, D)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    allclose(out, ref, rtol=rtol, atol=rtol)
    assert out.dtype == dtype


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 128, 128),
                                      (32, 128, 64)])
def test_phantom_fused_block_shapes(bm, bn, bk):
    M, K, N, PK = 128, 128, 128, 32
    x = rand(8, (M, K), scale=0.3)
    L = rand(9, (K, N), scale=0.3)
    g = rand(10, (M, PK), scale=0.3)
    D = rand(11, (PK, N), scale=0.3)
    out = phantom_fused_matmul(x, L, g, D, bm=bm, bn=bn, bk=bk,
                               interpret=True)
    ref = phantom_fused_ref(x, L, g, D)
    allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_kernel_matches_phantom_layer_math():
    """The kernel computes exactly the per-rank phantom forward: local
    update + concatenated decompress (self-term already zeroed in D)."""
    M, n_in_loc, n_out_loc, p, k = 128, 128, 128, 4, 32
    x = rand(12, (M, n_in_loc), scale=0.3)
    L = rand(13, (n_in_loc, n_out_loc), scale=0.3)
    g_all = rand(14, (M, p * k), scale=0.3)
    D = rand(15, (p * k, n_out_loc), scale=0.3)
    out = phantom_fused_matmul(x, L, g_all, D, interpret=True)
    z = x @ L + g_all @ D
    allclose(out, z, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,S,H,KV,hd,causal", [
    (2, 128, 4, 4, 32, True),
    (1, 256, 8, 2, 32, True),
    (2, 128, 4, 1, 64, True),
    (1, 128, 4, 4, 32, False),
])
def test_flash_attention_kernel(B, S, H, KV, hd, causal):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref
    q = rand(20, (B, S, H, hd), scale=0.5)
    k = rand(21, (B, S, KV, hd), scale=0.5)
    v = rand(22, (B, S, KV, hd), scale=0.5)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    allclose(out, ref, rtol=2e-3, atol=2e-4)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref
    B, S, H, KV, hd = 1, 128, 4, 2, 32
    q = rand(23, (B, S, H, hd), scale=0.5).astype(jnp.bfloat16)
    k = rand(24, (B, S, KV, hd), scale=0.5).astype(jnp.bfloat16)
    v = rand(25, (B, S, KV, hd), scale=0.5).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, interpret=True)
    ref = flash_attention_ref(q, k, v)
    allclose(out, ref, rtol=3e-2, atol=3e-2)
    assert out.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# shape handling: pad-and-slice to the tile grid (PR-10 bugfix #1) and the
# bpk-tiled ghost contraction (bugfix #2) — these shapes crashed the
# pre-fix kernel (bare AssertionError on M=192; full-PK ghost residency)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,PK", [
    (192, 128, 128, 64),       # M not a multiple of the 128 tile
    (192, 192, 192, 48),       # nothing divides 128
    (100, 72, 56, 24),         # small odd everything
    (130, 257, 129, 65),       # just past tile boundaries
    (128, 128, 300, 64),       # N padded
])
def test_phantom_fused_non_tile_multiple_shapes(M, K, N, PK):
    x = rand(30, (M, K), scale=0.3)
    L = rand(31, (K, N), scale=0.3)
    g = rand(32, (M, PK), scale=0.3)
    D = rand(33, (PK, N), scale=0.3)
    out = phantom_fused_matmul(x, L, g, D, interpret=True)
    ref = phantom_fused_ref(x, L, g, D)
    allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("PK,bpk", [(512, 128), (384, 128), (1024, 64)])
def test_phantom_fused_ghost_tiled_over_bpk(PK, bpk):
    """Large p*k ghost widths stream through bpk-wide blocks instead of
    sitting in VMEM at full width (the pre-fix OOM footgun)."""
    from repro.kernels.phantom_fused import kernel_vmem_bytes
    M, K, N = 128, 128, 128
    x = rand(34, (M, K), scale=0.3)
    L = rand(35, (K, N), scale=0.3)
    g = rand(36, (M, PK), scale=0.2)
    D = rand(37, (PK, N), scale=0.2)
    out = phantom_fused_matmul(x, L, g, D, bpk=bpk, interpret=True)
    allclose(out, phantom_fused_ref(x, L, g, D), rtol=5e-4, atol=5e-4)
    # the working set is bounded by the tile config, not by PK
    assert (kernel_vmem_bytes(128, 128, 128, bpk, jnp.float32)
            < kernel_vmem_bytes(128, 128, 128, PK, jnp.float32))


def test_phantom_fused_typed_errors():
    from repro.kernels.phantom_fused import (KernelConfigError,
                                             VMEM_BUDGET_BYTES,
                                             check_kernel_fits)
    x = rand(38, (64, 64))
    L = rand(39, (64, 64))
    g = rand(40, (64, 32))
    with pytest.raises(KernelConfigError, match="D shape"):
        phantom_fused_matmul(x, L, g, jnp.zeros((8, 8)), interpret=True)
    with pytest.raises(KernelConfigError, match="L rows"):
        phantom_fused_matmul(x, jnp.zeros((32, 64)), g,
                             jnp.zeros((32, 64)), interpret=True)
    # tile working set past the VMEM budget is a typed error, not an OOM
    with pytest.raises(KernelConfigError, match="VMEM"):
        check_kernel_fits(2048, 2048, 2048, 2048, jnp.float32)
    assert check_kernel_fits(128, 128, 128, 128,
                             jnp.float32) < VMEM_BUDGET_BYTES


# ---------------------------------------------------------------------------
# fused backward kernels + the custom_vjp op (PR-10 tentpole)
# ---------------------------------------------------------------------------

def test_backward_kernels_match_transpose_math():
    from repro.kernels.phantom_fused import matmul_nt, matmul_tn
    a = rand(41, (96, 160), scale=0.3)
    b = rand(42, (72, 160), scale=0.3)
    allclose(matmul_nt(a, b, interpret=True), a @ b.T,
             rtol=2e-4, atol=2e-4)
    c = rand(43, (96, 112), scale=0.3)
    allclose(matmul_tn(a, c, interpret=True), a.T @ c,
             rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N,k,p", [
    (128, 128, 128, 16, 4),
    (192, 96, 80, 8, 2),       # non-tile-multiple shapes through the vjp
    (64, 64, 64, 4, 8),
])
def test_phantom_fused_linear_grads(dtype, M, K, N, k, p):
    """custom_vjp fused backward vs jax.grad of the pure-jnp oracle,
    across dtype x shape x ghost width."""
    import jax
    from repro.kernels.ops import phantom_fused_linear
    PK = p * k
    x = rand(50, (M, K), scale=0.3).astype(dtype)
    L = rand(51, (K, N), scale=0.3).astype(dtype)
    g = rand(52, (M, PK), scale=0.3).astype(dtype)
    D = rand(53, (PK, N), scale=0.3).astype(dtype)

    def loss_kernel(x, L, g, D):
        return jnp.sum(jnp.square(
            phantom_fused_linear(x, L, g, D, interpret=True)))

    def loss_ref(x, L, g, D):
        return jnp.sum(jnp.square(phantom_fused_ref(x, L, g, D)))

    lk, gk = jax.value_and_grad(loss_kernel, argnums=(0, 1, 2, 3))(
        x, L, g, D)
    lr, gr = jax.value_and_grad(loss_ref, argnums=(0, 1, 2, 3))(
        x, L, g, D)
    tol = 6e-2 if dtype == jnp.bfloat16 else 2e-3
    allclose(lk, lr, rtol=tol, atol=tol)
    for name, a, b in zip(("dx", "dL", "dg", "dD"), gk, gr):
        assert a.dtype == dtype, name
        allclose(a, b, rtol=tol, atol=tol, msg=name)


def test_phantom_fused_linear_batch_dims():
    from repro.kernels.ops import phantom_fused_linear
    B, S, K, N, PK = 2, 24, 64, 48, 32
    x = rand(54, (B, S, K), scale=0.3)
    L = rand(55, (K, N), scale=0.3)
    g = rand(56, (B, S, PK), scale=0.3)
    D = rand(57, (PK, N), scale=0.3)
    out = phantom_fused_linear(x, L, g, D, interpret=True)
    assert out.shape == (B, S, N)
    ref = phantom_fused_ref(x.reshape(-1, K), L, g.reshape(-1, PK), D)
    allclose(out.reshape(-1, N), ref, rtol=2e-4, atol=2e-4)


def test_resolve_kernel_backend():
    import jax
    from repro.kernels.ops import resolve_kernel_backend
    assert resolve_kernel_backend("xla") == "xla"
    assert resolve_kernel_backend("pallas") == "pallas"
    expect = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert resolve_kernel_backend("auto") == expect
    with pytest.raises(ValueError, match="kernel_backend"):
        resolve_kernel_backend("cuda")


# ---------------------------------------------------------------------------
# trainer-level pin: the phantom FFN probe step (the trainer's schedule)
# must produce identical loss/grads under kernel_backend pallas vs xla
# ---------------------------------------------------------------------------

def _kernel_cfg(backend, n=128, L=2, k=8):
    from repro.configs.base import (ModelConfig, PhantomConfig,
                                    phantom_projection_map)
    return ModelConfig(name=f"kernel-pin-{backend}", family="ffn",
                       num_layers=L, d_model=n, ffn_width=n, ffn_depth=L,
                       mlp="relu", phantom=PhantomConfig(k=k),
                       projections=phantom_projection_map(
                           k, ffn_layer=True, kernel_backend=backend))


@pytest.mark.parametrize("meshname", ["mesh18", "mesh24"])
def test_ffn_step_pallas_matches_xla(meshname, request):
    import jax
    from repro.parallel.params import materialize
    from repro.telemetry.probe import make_ffn_probe_step
    mesh = request.getfixturevalue(meshname)
    batch = 16
    results = {}
    for backend in ("xla", "pallas"):
        cfg = _kernel_cfg(backend)
        fn, decls = make_ffn_probe_step(cfg, mesh, batch)
        params = materialize(decls, seed=5)
        x = rand(60, (batch, cfg.ffn_width), scale=0.5)
        y = rand(61, (batch, cfg.ffn_width), scale=0.5)
        loss, (gp, gx) = fn(params, x, y)
        results[backend] = (loss, gp, gx)
    lx, gpx, gxx = results["xla"]
    lp, gpp, gxp = results["pallas"]
    allclose(lx, lp, rtol=1e-5, atol=1e-6)
    leaves_x = jax.tree_util.tree_leaves_with_path(gpx)
    leaves_p = jax.tree_util.tree_leaves_with_path(gpp)
    assert [k for k, _ in leaves_x] == [k for k, _ in leaves_p]
    for (path, a), (_, b) in zip(leaves_x, leaves_p):
        allclose(a, b, rtol=1e-4, atol=1e-5,
                 msg=f"param grad {jax.tree_util.keystr(path)}")
    allclose(gxx, gxp, rtol=1e-4, atol=1e-5, msg="input grad")


# ---------------------------------------------------------------------------
# plumbing: comm/compute overlap XLA flags, config + planner backend knobs
# ---------------------------------------------------------------------------

def test_comm_overlap_flags():
    from repro.parallel.compat import (COMM_OVERLAP_FLAGS,
                                       comm_overlap_flags,
                                       enable_comm_overlap)
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" in \
        comm_overlap_flags("gpu")
    assert "--xla_tpu_enable_latency_hiding_scheduler=true" in \
        comm_overlap_flags("tpu")
    assert "async" in comm_overlap_flags("tpu")
    # cpu XLA rejects the accelerator flags -> the cpu entry MUST be empty
    assert comm_overlap_flags("cpu") == ""
    with pytest.raises(ValueError, match="platform"):
        comm_overlap_flags("rocm")
    assert set(COMM_OVERLAP_FLAGS) == {"cpu", "gpu", "tpu"}

    import os
    saved = os.environ.get("XLA_FLAGS")
    try:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        applied = enable_comm_overlap("gpu")
        assert applied == comm_overlap_flags("gpu")
        first = os.environ["XLA_FLAGS"]
        assert "--xla_gpu_enable_async_collectives=true" in first
        assert "device_count=8" in first          # existing flags kept
        assert enable_comm_overlap("gpu") == ""   # idempotent: no re-add
        assert os.environ["XLA_FLAGS"] == first
        assert enable_comm_overlap("cpu") == ""   # cpu is a no-op
    finally:
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved


def test_with_kernel_backend_config():
    from repro.configs.base import with_kernel_backend
    cfg = _kernel_cfg("xla")
    out = with_kernel_backend(cfg, "pallas")
    assert out.projections.ffn_layer.kernel_backend == "pallas"
    assert out.phantom.kernel_backend == "pallas"
    # entries that were None stay None (must NOT materialize a tensor
    # default — that would shadow the legacy ffn_impl shim)
    assert out.projections.attn_q is None
    assert cfg.projections.ffn_layer.kernel_backend == "xla"  # no mutation


def test_enumerate_plans_kernel_backends():
    from repro.planner.space import enumerate_plans
    plans = enumerate_plans(8, width=256, depth=2, batch=32,
                            ks=(8,), pps=(1,),
                            kernel_backends=("xla", "pallas"))
    phantom = [c for c in plans if c.strategy == "phantom"]
    tensor = [c for c in plans if c.strategy != "phantom"]
    assert {c.kernel_backend for c in phantom} == {"xla", "pallas"}
    # non-phantom candidates don't fan out over backends
    assert {c.kernel_backend for c in tensor} == {"xla"}
    pal = next(c for c in phantom if c.kernel_backend == "pallas")
    assert pal.name.endswith("_pallas")
    assert pal.spec().kernel_backend == "pallas"
