"""Pallas kernel validation (interpret=True on CPU; TPU is the target):
shape/dtype sweep against the pure-jnp oracle in kernels/ref.py."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.phantom_fused import phantom_fused_matmul
from repro.kernels.ref import phantom_fused_ref
from helpers import allclose, rand


@pytest.mark.parametrize("M,K,N,PK", [
    (128, 128, 128, 64),
    (256, 128, 128, 128),
    (128, 256, 384, 32),
    (512, 128, 256, 256),
    (128, 512, 128, 16),
])
def test_phantom_fused_shapes(M, K, N, PK):
    x = rand(0, (M, K), scale=0.3)
    L = rand(1, (K, N), scale=0.3)
    g = rand(2, (M, PK), scale=0.3)
    D = rand(3, (PK, N), scale=0.3)
    out = phantom_fused_matmul(x, L, g, D, interpret=True)
    ref = phantom_fused_ref(x, L, g, D)
    allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_phantom_fused_dtypes(dtype):
    M, K, N, PK = 128, 128, 128, 64
    x = rand(4, (M, K), scale=0.3).astype(dtype)
    L = rand(5, (K, N), scale=0.3).astype(dtype)
    g = rand(6, (M, PK), scale=0.3).astype(dtype)
    D = rand(7, (PK, N), scale=0.3).astype(dtype)
    out = phantom_fused_matmul(x, L, g, D, interpret=True)
    ref = phantom_fused_ref(x, L, g, D)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    allclose(out, ref, rtol=rtol, atol=rtol)
    assert out.dtype == dtype


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 128, 128),
                                      (32, 128, 64)])
def test_phantom_fused_block_shapes(bm, bn, bk):
    M, K, N, PK = 128, 128, 128, 32
    x = rand(8, (M, K), scale=0.3)
    L = rand(9, (K, N), scale=0.3)
    g = rand(10, (M, PK), scale=0.3)
    D = rand(11, (PK, N), scale=0.3)
    out = phantom_fused_matmul(x, L, g, D, bm=bm, bn=bn, bk=bk,
                               interpret=True)
    ref = phantom_fused_ref(x, L, g, D)
    allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_kernel_matches_phantom_layer_math():
    """The kernel computes exactly the per-rank phantom forward: local
    update + concatenated decompress (self-term already zeroed in D)."""
    M, n_in_loc, n_out_loc, p, k = 128, 128, 128, 4, 32
    x = rand(12, (M, n_in_loc), scale=0.3)
    L = rand(13, (n_in_loc, n_out_loc), scale=0.3)
    g_all = rand(14, (M, p * k), scale=0.3)
    D = rand(15, (p * k, n_out_loc), scale=0.3)
    out = phantom_fused_matmul(x, L, g_all, D, interpret=True)
    z = x @ L + g_all @ D
    allclose(out, z, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,S,H,KV,hd,causal", [
    (2, 128, 4, 4, 32, True),
    (1, 256, 8, 2, 32, True),
    (2, 128, 4, 1, 64, True),
    (1, 128, 4, 4, 32, False),
])
def test_flash_attention_kernel(B, S, H, KV, hd, causal):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref
    q = rand(20, (B, S, H, hd), scale=0.5)
    k = rand(21, (B, S, KV, hd), scale=0.5)
    v = rand(22, (B, S, KV, hd), scale=0.5)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    allclose(out, ref, rtol=2e-3, atol=2e-4)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref
    B, S, H, KV, hd = 1, 128, 4, 2, 32
    q = rand(23, (B, S, H, hd), scale=0.5).astype(jnp.bfloat16)
    k = rand(24, (B, S, KV, hd), scale=0.5).astype(jnp.bfloat16)
    v = rand(25, (B, S, KV, hd), scale=0.5).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, interpret=True)
    ref = flash_attention_ref(q, k, v)
    allclose(out, ref, rtol=3e-2, atol=3e-2)
    assert out.dtype == jnp.bfloat16
