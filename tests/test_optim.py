"""Optimizers: AdamW vs hand-computed reference, Adafactor memory
factoring and convergence, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamW, Adafactor, SGD
from repro.optim.schedules import constant, warmup_cosine, warmup_linear
from helpers import allclose, rand


def test_adamw_matches_reference_math():
    opt = AdamW(1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st = opt.init(p)
    newp, st = opt.update(g, st, p, jnp.int32(0))
    # step 1: m = 0.1*g, v = 0.001*g^2; mhat = g; vhat = g^2
    upd = np.asarray(g["w"]) / (np.abs(np.asarray(g["w"])) + 1e-8)
    ref = np.asarray(p["w"]) - 1e-2 * upd
    allclose(newp["w"], ref, rtol=1e-5)


def test_adamw_weight_decay_decoupled():
    opt = AdamW(1e-2, weight_decay=0.1)
    p = {"w": jnp.array([10.0])}
    g = {"w": jnp.array([0.0])}
    st = opt.init(p)
    newp, _ = opt.update(g, st, p, jnp.int32(0))
    allclose(newp["w"], jnp.array([10.0 - 1e-2 * 0.1 * 10.0]), rtol=1e-5)


def test_adafactor_state_is_factored():
    opt = Adafactor(1e-2)
    p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    st = opt.init(p)
    assert st["vr"]["w"].shape == (64,)
    assert st["vc"]["w"].shape == (32,)
    assert st["vr"]["b"].shape == (64,)   # unfactored for vectors


def test_adafactor_state_decls_drop_axes():
    from repro.parallel.params import ParamDecl
    from jax.sharding import PartitionSpec as P
    opt = Adafactor(1e-2)
    decls = {"w": ParamDecl((64, 32), P("tp", None))}
    sd = opt.state_decls(decls)
    assert sd["vr"]["w"].shape == (64,)
    assert sd["vr"]["w"].spec == P("tp")
    assert sd["vc"]["w"].shape == (32,)
    assert sd["vc"]["w"].spec == P()


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor", "sgd"])
def test_optimizers_descend_quadratic(opt_name):
    from repro.optim import make_optimizer
    opt = make_optimizer(opt_name, 0.1 if opt_name != "sgd" else 0.01)
    target = rand(0, (16, 8))
    p = {"w": jnp.zeros((16, 8))}
    st = opt.init(p)
    for s in range(200):
        g = {"w": 2 * (p["w"] - target)}
        p, st = opt.update(g, st, p, jnp.int32(s))
    err = float(jnp.mean(jnp.square(p["w"] - target)))
    assert err < 0.05, f"{opt_name}: {err}"


def test_schedules():
    s = warmup_cosine(1.0, warmup=10, total=110, floor_frac=0.1)
    assert float(s(jnp.int32(0))) < 0.2
    assert abs(float(s(jnp.int32(10))) - 1.0) < 0.1
    assert float(s(jnp.int32(109))) < 0.2
    s2 = warmup_linear(1.0, 10, 110)
    assert float(s2(jnp.int32(60))) < 1.0
    assert abs(float(constant(0.3)(jnp.int32(5))) - 0.3) < 1e-6


def test_sgd_momentum():
    opt = SGD(0.1, momentum=0.9)
    p = {"w": jnp.array([1.0])}
    st = opt.init(p)
    g = {"w": jnp.array([1.0])}
    p1, st = opt.update(g, st, p, jnp.int32(0))
    p2, st = opt.update(g, st, p1, jnp.int32(1))
    # second step is larger (momentum accumulates)
    d1 = 1.0 - float(p1["w"][0])
    d2 = float(p1["w"][0]) - float(p2["w"][0])
    assert d2 > d1
