"""HLO collective parser + roofline/energy model unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.energy import (RooflineTerms, comm_time_us,
                               energy_to_loss, roofline_terms)
from repro.launch.hlo_analysis import collective_bytes
from helpers import smap


def test_parser_finds_collectives(mesh18):
    def f(x):
        g = jax.lax.all_gather(x, "model")          # AG [8, 8, 16]
        s = jax.lax.psum(jnp.sum(g), "model")       # AR
        y = jax.lax.psum_scatter(
            g * s, "model", scatter_dimension=0, tiled=False)  # RS
        return y

    fn = smap(f, mesh18, P(None, "model"), P(None, "model"))
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    compiled = fn.lower(x).compile()
    total, breakdown = collective_bytes(compiled.as_text(),
                                        default_group=8)
    assert total > 0
    ops = set(breakdown)
    assert "all-gather" in ops or "all-reduce" in ops
    for rec in breakdown.values():
        assert rec["count"] >= 1
        assert rec["wire_bytes"] > 0


def test_wire_bytes_math():
    hlo = """
  %ag = f32[8,16,128]{2,1,0} all-gather(f32[16,128] %x), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128] %y), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
"""
    total, breakdown = collective_bytes(hlo, default_group=8)
    ag = breakdown["all-gather"]
    # result 8*16*128*4 bytes; wire = result * 7/8
    assert abs(ag["wire_bytes"] - 8 * 16 * 128 * 4 * 7 / 8) < 1
    ar = breakdown["all-reduce"]
    assert abs(ar["wire_bytes"] - 2 * 16 * 128 * 4 * 7 / 8) < 1


def test_iota_replica_groups():
    hlo = ("  %rs = bf16[4,64]{1,0} reduce-scatter(bf16[4,64] %x), "
           "replica_groups=[2,256]<=[512], dimensions={0}\n")
    total, breakdown = collective_bytes(hlo, default_group=16)
    assert breakdown["reduce-scatter"]["count"] == 1
    # group size 256: wire = result * 255
    expect = 4 * 64 * 2 * 255
    assert abs(breakdown["reduce-scatter"]["wire_bytes"] - expect) < 1


def test_roofline_terms_dominance():
    rt = roofline_terms(1e12, 1e9, 1e6)
    assert rt.dominant == "compute"
    rt2 = roofline_terms(1e9, 1e12, 1e6)
    assert rt2.dominant == "memory"
    rt3 = roofline_terms(1e9, 1e9, 1e12)
    assert rt3.dominant == "collective"
    assert 0 < rt.fraction_of_roofline() <= 1


def test_energy_model_paper_constants():
    # paper Appendix: reduce-scatter fit c1=145.5, c2=2.4e-3 us
    t = comm_time_us("reduce_scatter", 1e6, 256)
    assert t > 2.4e-3 * 1e6          # bandwidth term dominates large m
    e = energy_to_loss(0.01, 0.002, p=256, iterations=453)
    assert e > 0
