"""Elastic runtime: deterministic seeded-fault fixtures.

Every scenario the fault-injection campaign needs pinned down, each on
a virtual clock (no sleeps), tiny widths and the audit gate off (the
audit's own behavior is covered by test_analysis; the smoke suite runs
it end-to-end)."""
import numpy as np
import pytest

from repro.train.elastic import ElasticConfig, run_elastic
from repro.train.fault import FaultScript


def _cfg(tmp_path, **kw):
    base = dict(workdir=str(tmp_path / "elastic"), devices=8, hosts=4,
                width=32, depth=2, batch=16, target_loss=1e-9,
                max_steps=24, checkpoint_every=5, ks=(4,),
                audit_replan=False, heartbeat_timeout_s=2.5,
                initial_strategy="tensor_col")
    base.update(kw)
    return ElasticConfig(**base)


def _quiet(*a, **k):
    pass


def test_no_faults_runs_clean(tmp_path):
    res = run_elastic(_cfg(tmp_path, max_steps=12), log_fn=_quiet)
    assert not res.aborted
    assert res.final_step == 12
    assert res.recoveries == []
    assert len(res.phases) == 1
    assert res.account["replay_overhead_ratio"] == 0.0
    assert res.account["steps_total"] == 12
    assert len(res.losses) == 12


def test_recovery_resumes_from_checkpoint(tmp_path):
    """Kill at 12: latest complete checkpoint is 10, detection lands a
    few (timeout/dt) steps later, the gap is replayed."""
    res = run_elastic(_cfg(tmp_path),
                      fault_script=FaultScript(kills=((12, "host3"),)),
                      log_fn=_quiet)
    assert not res.aborted
    assert res.final_step == 24
    assert len(res.recoveries) == 1
    rec = res.recoveries[0]
    assert rec["restored_step"] == 10
    assert rec["detect_step"] > 12          # detection lag, not instant
    assert rec["replayed_steps"] == rec["detect_step"] - 10
    assert not rec["from_scratch"]
    assert rec["dead_hosts"] == ["host3"]
    assert len(res.phases) == 2
    assert res.phases[1]["restart"]
    assert res.account["replayed_steps"] == rec["replayed_steps"]
    assert res.account["restarts"] == 1


def test_kill_during_warmup_restarts_from_scratch(tmp_path):
    """A fault before the first checkpoint cadence leaves nothing to
    restore — the recovery restarts from step 0 and still completes."""
    res = run_elastic(_cfg(tmp_path, max_steps=14),
                      fault_script=FaultScript(kills=((2, "host1"),)),
                      log_fn=_quiet)
    assert not res.aborted
    assert res.final_step == 14
    assert len(res.recoveries) == 1
    rec = res.recoveries[0]
    assert rec["from_scratch"]
    assert rec["restored_step"] == 0
    assert rec["replayed_steps"] == rec["detect_step"]


def test_double_fault(tmp_path):
    """Two separate host losses: two recoveries, both survived, and the
    account counts both restarts."""
    res = run_elastic(
        _cfg(tmp_path, max_steps=30),
        fault_script=FaultScript(kills=((7, "host1"), (18, "host2"))),
        log_fn=_quiet)
    assert not res.aborted
    assert res.final_step == 30
    assert len(res.recoveries) == 2
    assert res.recoveries[0]["dead_hosts"] == ["host1"]
    assert res.recoveries[1]["dead_hosts"] == ["host1", "host2"]
    assert len(res.phases) == 3
    assert res.account["restarts"] == 2


def test_all_hosts_dead_aborts(tmp_path):
    res = run_elastic(
        _cfg(tmp_path),
        fault_script=FaultScript(kills=tuple(
            (3, f"host{i}") for i in range(4))),
        log_fn=_quiet)
    assert res.aborted
    assert not res.reached_target


def test_max_restarts_exhausted_aborts(tmp_path):
    res = run_elastic(_cfg(tmp_path, max_restarts=0),
                      fault_script=FaultScript(kills=((6, "host2"),)),
                      log_fn=_quiet)
    assert res.aborted
    assert res.recoveries == []


def test_phantom_downsize_distills(tmp_path):
    """The paper-sanctioned downsize: tensor on the full budget, fault,
    re-plan restricted to the phantom family — the checkpoint is
    SVD-distilled into the (k, tp) factor class on fewer devices."""
    res = run_elastic(
        _cfg(tmp_path, strategies=("phantom",),
             initial_strategy="tensor_col"),
        fault_script=FaultScript(kills=((12, "host3"),)),
        log_fn=_quiet)
    assert not res.aborted
    rec = res.recoveries[0]
    assert rec["distilled"]
    assert rec["devices_after"] < rec["devices_before"]
    assert res.phases[0]["strategy"] == "tensor_col"
    assert res.phases[1]["strategy"] == "phantom"
    # training continued and improved after the class change
    assert res.losses[-1] < res.losses[0]


def test_kill_during_async_save(tmp_path, monkeypatch):
    """Fault detected while a save is still in the write queue: the
    recovery path flushes first, so the in-flight checkpoint commits and
    is what training restores from."""
    import time as _time

    from repro.train.checkpoint import CheckpointManager
    orig = CheckpointManager._write

    def slow_write(self, step, host, meta):
        _time.sleep(0.25)
        orig(self, step, host, meta)

    monkeypatch.setattr(CheckpointManager, "_write", slow_write)
    res = run_elastic(_cfg(tmp_path, max_steps=18),
                      fault_script=FaultScript(kills=((10, "host0"),)),
                      log_fn=_quiet)
    assert not res.aborted
    rec = res.recoveries[0]
    # the step-10 save was in flight at detection; flush committed it
    assert rec["restored_step"] == 10
    assert not rec["from_scratch"]


def test_account_consistency(tmp_path):
    res = run_elastic(_cfg(tmp_path),
                      fault_script=FaultScript(kills=((12, "host3"),)),
                      log_fn=_quiet)
    a = res.account
    np.testing.assert_allclose(
        a["energy_j_total"],
        a["energy_j_useful"] + a["energy_j_replay"]
        + a["energy_j_ckpt_io"] + a["energy_j_restart"], rtol=1e-9)
    assert a["steps_total"] == sum(p["steps"] for p in res.phases)
    assert a["replayed_steps"] == sum(p["replayed_steps"]
                                      for p in res.phases)
    step_j = a["energy_j_useful"] + a["energy_j_replay"]
    np.testing.assert_allclose(a["replay_overhead_ratio"],
                               a["energy_j_replay"] / step_j, rtol=1e-9)
    assert 0.0 < a["replay_overhead_ratio"] < 1.0
    assert a["restarts"] == 1
    assert a["schema"] == "recovery-account/v1"


def test_ledger_entry_recorded(tmp_path):
    from repro.telemetry import Ledger
    ledger = Ledger(run="test")
    res = run_elastic(_cfg(tmp_path, max_steps=12),
                      fault_script=FaultScript(kills=((6, "host1"),)),
                      ledger=ledger, log_fn=_quiet)
    rows = [e for e in ledger.entries if e.kind == "elastic"]
    assert len(rows) == 1
    e = rows[0]
    assert e.suite == "elastic"
    assert e.name == "elastic_ffn32"
    assert set(e.predicted) == {"energy_j_total", "energy_j_useful",
                                "energy_j_replay"}
    assert e.extra["recovery"]["schema"] == "recovery-account/v1"
    assert len(e.extra["recoveries"]) == 1
    assert e.extra["plans"] == res.plan_names


def test_devices_must_divide_hosts(tmp_path):
    with pytest.raises(ValueError, match="divide"):
        run_elastic(_cfg(tmp_path, devices=6, hosts=4), log_fn=_quiet)
