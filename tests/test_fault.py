"""Fault tolerance: virtual-clock heartbeat detection, straggler
flagging + metered-loop wiring, restart policy, fault scripting, and the
end-to-end kill-and-restore equivalence — all deterministic, no sleeps."""
import jax.numpy as jnp
import numpy as np

from repro.train.fault import (FaultScript, RestartPolicy,
                               SimulatedCluster, StragglerDetector,
                               VirtualClock, note_step_time)


def test_heartbeat_detects_dead_host_virtual(tmp_path):
    cl = SimulatedCluster(str(tmp_path), hosts=4, timeout_s=2.5,
                          virtual=True)
    cl.tick(step=1)
    assert cl.check() == []
    cl.kill("host2")
    # staleness is a pure function of the virtual clock: just under the
    # timeout the host is still considered alive...
    cl.advance(2.5)
    cl.tick(step=2)
    assert cl.check() == []
    # ...one more tick past it, dead — exactly the detection lag a real
    # deployment pays
    cl.advance(1.0)
    cl.tick(step=3)
    assert cl.check() == ["host2"]


def test_virtual_clock_is_shared(tmp_path):
    cl = SimulatedCluster(str(tmp_path), hosts=2, timeout_s=1.0,
                          virtual=True)
    assert isinstance(cl.clock, VirtualClock)
    assert cl.monitor.clock is cl.clock
    assert all(hb.clock is cl.clock for hb in cl.hbs.values())


def test_all_hosts_dead(tmp_path):
    cl = SimulatedCluster(str(tmp_path), hosts=3, timeout_s=1.0,
                          virtual=True)
    cl.tick(0)
    for h in list(cl.hosts):
        cl.kill(h)
    cl.advance(2.0)
    assert cl.check() == ["host0", "host1", "host2"]


def test_straggler_detector():
    det = StragglerDetector(window=20, threshold=2.0)
    for s in range(20):
        assert not det.record(s, 0.1)
    assert det.record(20, 0.5)          # 5x median -> flagged
    assert not det.record(21, 0.12)
    assert len(det.flagged) == 1


def test_straggler_needs_history():
    """No flags until the trailing window has >= 10 samples — a slow
    compile-adjacent early step must not fire the policy."""
    det = StragglerDetector(window=20, threshold=2.0)
    for s in range(9):
        det.record(s, 0.1)
    assert not det.record(9, 99.0)
    assert det.flagged == []


def test_note_step_time_wiring():
    """The shared metered-loop hook: healthy steps return None; a flagged
    straggler emits a ledger event (kind ``fault``) and returns the
    policy decision."""
    from repro.telemetry import Ledger
    det = StragglerDetector(window=20, threshold=2.0)
    pol = RestartPolicy(checkpoint_on_straggler=True)
    ledger = Ledger(run="test")
    for s in range(15):
        assert note_step_time(det, pol, s, 0.1, ledger) is None
    decision = note_step_time(det, pol, 15, 1.0, ledger,
                              name="unit", arch="ffn", impl="tensor", p=2)
    assert decision == "checkpoint"
    faults = [e for e in ledger.entries if e.kind == "fault"]
    assert len(faults) == 1
    e = faults[0]
    assert e.name == "unit_step15"
    assert e.extra["event"] == "straggler"
    assert e.extra["decision"] == "checkpoint"
    assert e.measured["slowdown"] > 2.0
    # stragglers warn, they don't consume the restart budget
    assert pol.restarts == 0


def test_note_step_time_no_detector():
    assert note_step_time(None, RestartPolicy(), 0, 1.0) is None


def test_restart_policy_limits():
    pol = RestartPolicy(max_restarts=2)
    assert pol.on_host_failure(["h1"], None) == "restore"
    assert pol.on_host_failure(["h1"], None) == "restore"
    assert pol.on_host_failure(["h1"], None) == "abort"


def test_restart_policy_straggler_decision():
    assert RestartPolicy().on_straggler(3, 1.0) == "checkpoint"
    assert RestartPolicy(
        checkpoint_on_straggler=False).on_straggler(3, 1.0) == "log"


def test_fault_script():
    fs = FaultScript(kills=((5, "host1"), (5, "host2"), (9, "host0")))
    assert fs.hosts_at(5) == ["host1", "host2"]
    assert fs.hosts_at(6) == []
    assert fs.kill_steps == [5, 9]
    assert FaultScript().hosts_at(0) == []


def test_kill_restore_end_to_end(mesh24, tmp_path):
    """Simulated failure mid-training: detect (virtual clock), restore
    from checkpoint, continue — final loss identical to an uninterrupted
    run."""
    from repro.configs.base import ShapeConfig, get_config
    from repro.launch.specs import input_specs
    from repro.optim import make_optimizer
    from repro.parallel.axes import MeshAxes
    from repro.parallel.params import materialize
    from repro.train.checkpoint import CheckpointManager
    from repro.train.trainer import make_train_step
    from helpers import make_batch

    cfg = get_config("phi3-mini-3.8b", smoke=True)
    axes = MeshAxes.from_mesh(mesh24)
    _, spec = input_specs(cfg, ShapeConfig("s", 64, 8, "train"), axes)
    opt = make_optimizer("adamw", 1e-3)
    step_fn, decls, opt_decls = make_train_step(cfg, mesh24, opt,
                                                batch_spec=spec)
    mgr = CheckpointManager(str(tmp_path))
    cl = SimulatedCluster(str(tmp_path / "hb"), hosts=2, timeout_s=0.5,
                          virtual=True)

    # run A: uninterrupted
    pA = materialize(decls, 0)
    oA = opt.init(pA)
    for s in range(4):
        pA, oA, mA = step_fn(pA, oA, jnp.int32(s),
                             make_batch(cfg, 8, 64, seed=s))

    # run B: checkpoint at 2, kill a host, detect, restore, resume
    pB = materialize(decls, 0)
    oB = opt.init(pB)
    for s in range(2):
        cl.tick(s)
        cl.advance(0.1)
        pB, oB, _ = step_fn(pB, oB, jnp.int32(s),
                            make_batch(cfg, 8, 64, seed=s))
    mgr.save(2, pB, oB)
    cl.kill("host1")
    cl.advance(1.0)
    cl.tick(2)
    dead = cl.check()
    assert dead == ["host1"]
    pol = RestartPolicy()
    assert pol.on_host_failure(dead, None) == "restore"
    st = mgr.restore_latest(decls, opt_decls, mesh24)
    pB, oB = st.params, st.opt_state
    for s in range(2, 4):
        pB, oB, mB = step_fn(pB, oB, jnp.int32(s),
                             make_batch(cfg, 8, 64, seed=s))
    np.testing.assert_allclose(float(mA["loss"]), float(mB["loss"]),
                               rtol=1e-6)
