"""Fault tolerance: heartbeat failure detection, straggler flagging,
restart policy, end-to-end kill-and-restore."""
import time

import jax.numpy as jnp
import numpy as np

from repro.train.fault import (Heartbeat, RestartPolicy, SimulatedCluster,
                               StragglerDetector)


def test_heartbeat_detects_dead_host(tmp_path):
    cl = SimulatedCluster(str(tmp_path), hosts=4, timeout_s=0.3)
    cl.tick(step=1)
    assert cl.check() == []
    cl.kill("host2")
    time.sleep(0.4)
    cl.tick(step=2)
    assert cl.check() == ["host2"]


def test_straggler_detector():
    det = StragglerDetector(window=20, threshold=2.0)
    for s in range(20):
        assert not det.record(s, 0.1)
    assert det.record(20, 0.5)          # 5x median -> flagged
    assert not det.record(21, 0.12)
    assert len(det.flagged) == 1


def test_restart_policy_limits():
    pol = RestartPolicy(max_restarts=2)
    assert pol.on_host_failure(["h1"], None) == "restore"
    assert pol.on_host_failure(["h1"], None) == "restore"
    assert pol.on_host_failure(["h1"], None) == "abort"


def test_kill_restore_end_to_end(mesh24, tmp_path):
    """Simulated failure mid-training: detect, restore from checkpoint,
    continue — final state identical to an uninterrupted run."""
    from repro.configs.base import ShapeConfig, get_config
    from repro.launch.specs import input_specs
    from repro.optim import make_optimizer
    from repro.parallel.axes import MeshAxes
    from repro.parallel.params import materialize
    from repro.train.checkpoint import CheckpointManager
    from repro.train.trainer import make_train_step
    from helpers import make_batch

    cfg = get_config("phi3-mini-3.8b", smoke=True)
    axes = MeshAxes.from_mesh(mesh24)
    _, spec = input_specs(cfg, ShapeConfig("s", 64, 8, "train"), axes)
    opt = make_optimizer("adamw", 1e-3)
    step_fn, decls, opt_decls = make_train_step(cfg, mesh24, opt,
                                                batch_spec=spec)
    mgr = CheckpointManager(str(tmp_path))
    cl = SimulatedCluster(str(tmp_path / "hb"), hosts=2, timeout_s=0.2)

    # run A: uninterrupted
    pA = materialize(decls, 0)
    oA = opt.init(pA)
    for s in range(4):
        pA, oA, mA = step_fn(pA, oA, jnp.int32(s),
                             make_batch(cfg, 8, 64, seed=s))

    # run B: checkpoint at 2, kill a host, detect, restore, resume
    pB = materialize(decls, 0)
    oB = opt.init(pB)
    for s in range(2):
        cl.tick(s)
        pB, oB, _ = step_fn(pB, oB, jnp.int32(s),
                            make_batch(cfg, 8, 64, seed=s))
    mgr.save(2, pB, oB)
    cl.kill("host1")
    time.sleep(0.3)
    cl.tick(2)
    dead = cl.check()
    assert dead == ["host1"]
    pol = RestartPolicy()
    assert pol.on_host_failure(dead, None) == "restore"
    st = mgr.restore_latest(decls, opt_decls, mesh24)
    pB, oB = st.params, st.opt_state
    for s in range(2, 4):
        pB, oB, mB = step_fn(pB, oB, jnp.int32(s),
                             make_batch(cfg, 8, 64, seed=s))
    np.testing.assert_allclose(float(mA["loss"]), float(mB["loss"]),
                               rtol=1e-6)
