"""HLO-parsing edge cases for the measured half of the energy ledger.

``collective_bytes`` / ``analyze_*`` must stay correct on the shapes
XLA actually emits: modules with no collectives at all, fused variadic
all-reduces whose result is a tuple, async ``-start``/``-done`` pairs
(one transfer, two HLO lines), degenerate single-member groups, and
collective-permutes whose group is spelled as ``source_target_pairs``
rather than ``replica_groups``.
"""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import collective_bytes
from repro.telemetry.compiled import (analyze_lowerable, clear_analysis_cache,
                                      collective_m_floats)


def test_zero_collective_module():
    """A purely local computation prices no collective traffic."""
    fn = jax.jit(lambda x: jnp.sin(x) @ x.T)
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    costs = analyze_lowerable(fn, x, default_group=8)
    assert costs.collectives == {}
    assert costs.collective_wire_bytes == 0.0
    assert costs.collective_m_floats == 0.0
    assert costs.flops > 0


def test_fused_variadic_all_reduce_tuple_result():
    """XLA fuses independent psums into one variadic all-reduce whose
    result is a TUPLE; bytes must sum over every element."""
    hlo = ("  %ar = (f32[64,128]{1,0}, f32[256]{0}) "
           "all-reduce(f32[64,128] %a, f32[256] %b), "
           "replica_groups={{0,1,2,3}}, to_apply=%add\n")
    _, breakdown = collective_bytes(hlo, default_group=8)
    rec = breakdown["all-reduce"]
    rb = (64 * 128 + 256) * 4
    assert rec["count"] == 1
    assert rec["result_bytes"] == rb
    assert abs(rec["wire_bytes"] - 2 * rb * 3 / 4) < 1
    # groups map keyed by the op's OWN replica group, not the default
    assert set(rec["groups"]) == {4}
    assert rec["groups"][4]["m_floats"] == 64 * 128 + 256


def test_async_start_done_counted_once():
    """An async pair is ONE transfer: count the -start, skip the
    -done."""
    hlo = (
        "  %ags = (f32[16,128], f32[128,128]) all-gather-start("
        "f32[16,128] %x), replica_groups={{0,1,2,3,4,5,6,7}}, "
        "dimensions={0}\n"
        "  %agd = f32[128,128] all-gather-done("
        "(f32[16,128], f32[128,128]) %ags)\n")
    _, breakdown = collective_bytes(hlo, default_group=8)
    assert set(breakdown) == {"all-gather"}
    assert breakdown["all-gather"]["count"] == 1


def test_bf16_counts_half_a_float():
    hlo = ("  %ar = bf16[1024]{0} all-reduce(bf16[1024] %x), "
           "replica_groups={{0,1}}, to_apply=%add\n")
    _, breakdown = collective_bytes(hlo, default_group=2)
    # paper units are 4-byte floats: 1024 bf16 = 512 float units
    assert breakdown["all-reduce"]["m_floats"] == 512.0
    assert collective_m_floats(breakdown, 2) == 512.0


def test_permute_group_from_source_target_pairs_ring():
    """A ring rotation over a 4-member axis has no replica_groups; the
    pair graph's connected component is the axis."""
    hlo = ("  %cp = f32[64,32]{1,0} collective-permute(f32[64,32] %x), "
           "source_target_pairs={{0,1},{1,2},{2,3},{3,0}}\n")
    _, breakdown = collective_bytes(hlo, default_group=16)
    rec = breakdown["collective-permute"]
    assert set(rec["groups"]) == {4}
    # permute wire = result, independent of the inferred group
    assert rec["wire_bytes"] == 64 * 32 * 4


def test_permute_group_from_pairs_1f1b_hop():
    """A 1F1B stage boundary is an OPEN hop (no wraparound): stage 0
    sends to stage 1 across dp=2 x tp=2 replicas — components of size
    2, the pp axis."""
    hlo = ("  %cp = f32[8,64]{1,0} collective-permute(f32[8,64] %x), "
           "source_target_pairs={{0,4},{1,5},{2,6},{3,7}}\n")
    _, breakdown = collective_bytes(hlo, default_group=8)
    assert set(breakdown["collective-permute"]["groups"]) == {2}


def test_degenerate_group_of_one_has_zero_wire():
    hlo = ("  %ag = f32[4,64]{1,0} all-gather(f32[4,64] %x), "
           "replica_groups={{0},{1},{2},{3}}, dimensions={0}\n")
    total, breakdown = collective_bytes(hlo, default_group=4)
    assert total == 0.0
    assert breakdown["all-gather"]["groups"][1]["count"] == 1


def test_mixed_groups_bucketed_separately():
    """One module using two mesh axes must keep per-axis buckets (the
    audit matches collectives by axis, the aggregate can't)."""
    hlo = (
        "  %a = f32[1024]{0} all-reduce(f32[1024] %x), "
        "replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add\n"
        "  %b = f32[2048]{0} all-reduce(f32[2048] %y), "
        "replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add\n")
    _, breakdown = collective_bytes(hlo, default_group=8)
    groups = breakdown["all-reduce"]["groups"]
    assert set(groups) == {2, 4}
    assert groups[4]["m_floats"] == 1024.0
    assert groups[2]["m_floats"] == 2048.0
    assert breakdown["all-reduce"]["count"] == 2


def test_analysis_cache_hit_same_module(mesh18):
    """Analyzing the same lowered module twice returns the SAME memoized
    record (one parse per process, the planner/audit contract)."""
    from jax.sharding import PartitionSpec as P
    from helpers import smap
    clear_analysis_cache()

    def f(x):
        return jax.lax.psum(x, "model")

    fn = smap(f, mesh18, P(None, None), P(None, None))
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    c1 = analyze_lowerable(fn, x, default_group=8)
    c2 = analyze_lowerable(fn, x, default_group=8)
    assert c1 is c2
