"""Per-arch smoke tests (deliverable f): every assigned architecture, as a
REDUCED same-family config, runs one train step on the local mesh with
finite loss and a decreasing trend over a few steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, ShapeConfig, get_config
from repro.launch.specs import input_specs
from repro.optim import make_optimizer
from repro.parallel.axes import MeshAxes
from repro.parallel.params import materialize
from repro.train.trainer import make_train_step
from helpers import make_batch

B, S = 8, 64


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step(mesh24, arch):
    cfg = get_config(arch, smoke=True)
    axes = MeshAxes.from_mesh(mesh24)
    shape = ShapeConfig("smoke", S, B, "train")
    _, spec = input_specs(cfg, shape, axes)
    opt = make_optimizer("adamw", 1e-3)
    step_fn, decls, _opt_decls = make_train_step(cfg, mesh24, opt,
                                                 batch_spec=spec)
    params = materialize(decls, 0)
    opt_state = opt.init(params)
    losses = []
    for s in range(3):
        batch = make_batch(cfg, B, S, seed=s)
        params, opt_state, m = step_fn(params, opt_state, jnp.int32(s),
                                       batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1]), f"{arch} loss not finite"
    assert losses[-1] < losses[0] + 0.5, f"{arch} diverging: {losses}"
    # output params stay finite
    flat = jax.tree.leaves(params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in flat[:4])


@pytest.mark.parametrize("arch", ["chatglm3-6b", "mamba2-370m",
                                  "jamba-1.5-large-398b"])
def test_arch_fsdp_variant(mesh24, arch):
    """FSDP param sharding (used by the >=72B archs) trains too."""
    cfg = get_config(arch, smoke=True).replace(fsdp=True)
    axes = MeshAxes.from_mesh(mesh24)
    shape = ShapeConfig("smoke", S, B, "train")
    _, spec = input_specs(cfg, shape, axes)
    opt = make_optimizer("adafactor", 1e-3)
    step_fn, decls, _ = make_train_step(cfg, mesh24, opt, batch_spec=spec)
    params = materialize(decls, 0)
    opt_state = opt.init(params)
    batch = make_batch(cfg, B, S)
    params, opt_state, m = step_fn(params, opt_state, jnp.int32(0), batch)
    assert np.isfinite(float(m["loss"]))


def test_dense_vs_phantom_param_counts():
    """The phantom variant of an arch is a smaller model (paper Table I)."""
    from repro.configs.base import dense_projection_map
    from repro.models.model import count_params
    cfg = get_config("qwen2.5-14b")
    dense = cfg.replace(projections=dense_projection_map())
    assert count_params(cfg, tp=16) < count_params(dense, tp=16)


def test_full_config_geometries():
    """The exact assigned geometries load and report sane param counts."""
    from repro.models.model import count_params
    expected_order = {
        "granite-moe-3b-a800m": (1e9, 8e9),
        "olmoe-1b-7b": (4e9, 12e9),
        "chatglm3-6b": (4e9, 10e9),
        "qwen2.5-14b": (10e9, 20e9),
        "stablelm-3b": (2e9, 5e9),
        "phi3-mini-3.8b": (2.5e9, 6e9),
        "mamba2-370m": (0.2e9, 0.8e9),
        "qwen2-vl-72b": (55e9, 90e9),
        "jamba-1.5-large-398b": (300e9, 480e9),
        "seamless-m4t-large-v2": (1e9, 4e9),
    }
    from repro.configs.base import dense_projection_map
    for arch, (lo, hi) in expected_order.items():
        cfg = get_config(arch)
        dense = cfg.replace(projections=dense_projection_map())
        n = count_params(dense, tp=16)
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
