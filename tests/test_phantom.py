"""Phantom parallelism core correctness: the sharded implementation must
compute exactly the block-structured dense matrix the paper defines, for
every execution variant, and the custom autograd collective (paper
Algorithm 1) must agree with JAX-native autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import PhantomConfig
from repro.core.autograd import all_gather_ghosts
from repro.core.phantom import (phantom_apply, phantom_decls,
                                phantom_dense_equivalent,
                                phantom_param_count)
from repro.parallel.axes import MeshAxes
from repro.parallel.params import materialize, param_count
from helpers import allclose, rand, resolved_param_specs, smap


def _apply_sharded(mesh, pp, params, x):
    axes = MeshAxes.from_mesh(mesh)
    decls = phantom_decls(x.shape[-1], params["D"].shape[2],
                          params["C"].shape[1], axes.tp)
    pspecs = resolved_param_specs(decls, mesh)
    f = smap(lambda p, xx: phantom_apply(pp, p, xx, axes),
             mesh, (pspecs, P(("data",), "model")),
             P(("data",), "model"))
    return f(params, x)


@pytest.mark.parametrize("variant", ["faithful", "fused", "ring"])
@pytest.mark.parametrize("self_term", [False, True])
def test_phantom_equals_dense_equivalent(mesh24, variant, self_term):
    n_in, n_out, k, B = 32, 48, 3, 8
    pp = PhantomConfig(k=k, variant=variant, include_self_term=self_term)
    axes = MeshAxes.from_mesh(mesh24)
    decls = phantom_decls(n_in, n_out, k, axes.tp)
    params = materialize(decls, seed=1)
    x = rand(0, (B, n_in))
    out = _apply_sharded(mesh24, pp, params, x)
    W = phantom_dense_equivalent(params, include_self_term=self_term)
    allclose(out, x @ W + params["b"], rtol=1e-4, atol=1e-5,
             msg=f"variant={variant}")


def test_variants_identical(mesh24):
    """faithful / fused / ring are the same function."""
    n, k, B = 64, 4, 8
    axes = MeshAxes.from_mesh(mesh24)
    decls = phantom_decls(n, n, k, axes.tp)
    params = materialize(decls, seed=2)
    x = rand(1, (B, n))
    outs = [_apply_sharded(mesh24, PhantomConfig(k=k, variant=v), params, x)
            for v in ("faithful", "fused", "ring")]
    allclose(outs[0], outs[1], rtol=1e-5)
    allclose(outs[0], outs[2], rtol=1e-5)


@pytest.mark.parametrize("variant", ["faithful", "fused", "ring"])
def test_gradients_match_dense_equivalent(mesh24, variant):
    """d(loss)/d(params) through the sharded collectives == gradients of
    the dense-equivalent computation (paper Eqns. 15-21)."""
    n, k, B = 32, 2, 4
    pp = PhantomConfig(k=k, variant=variant)
    axes = MeshAxes.from_mesh(mesh24)
    decls = phantom_decls(n, n, k, axes.tp)
    pspecs = resolved_param_specs(decls, mesh24)
    params = materialize(decls, seed=3)
    x = rand(2, (B, n))
    y = rand(3, (B, n))

    def sharded_loss(p, xx, yy):
        # differentiate the LOCAL share (out is fully sharded); psum'ing
        # the scalar pre-grad would scale grads by the device count
        # (psum's transpose under shard_map is psum)
        out = phantom_apply(pp, p, xx, axes)
        return jnp.sum((out - yy) ** 2)

    gfn = smap(lambda p, xx, yy: jax.tree.map(
        lambda g: jax.lax.psum(g, ("data",)),
        jax.grad(sharded_loss)(p, xx, yy)),
        mesh24, (pspecs, P("data", "model"), P("data", "model")), pspecs)
    g_sharded = gfn(params, x, y)

    def dense_loss(p, xx, yy):
        W = phantom_dense_equivalent(p)
        out = xx @ W + p["b"]
        return jnp.sum((out - yy) ** 2)

    g_dense = jax.grad(dense_loss)(params, x, y)
    for key in ("L", "C", "D", "b"):
        allclose(g_sharded[key], g_dense[key], rtol=3e-3, atol=1e-4,
                 msg=f"grad {key} variant={variant}")


def test_custom_allgather_matches_native(mesh18):
    """Paper Algorithm 1 (custom_vjp) == lax.all_gather autodiff."""
    B, k = 4, 8
    x = rand(5, (32, k))

    def f_custom(xx):
        g = all_gather_ghosts(xx, "model")
        return jnp.sum(g * g * jnp.arange(8).reshape(8, 1, 1))

    def f_native(xx):
        g = jax.lax.all_gather(xx, "model")
        return jnp.sum(g * g * jnp.arange(8).reshape(8, 1, 1))

    gc = smap(jax.grad(f_custom), mesh18, P(None, "model"), P(None, "model"))
    gn = smap(jax.grad(f_native), mesh18, P(None, "model"), P(None, "model"))
    allclose(gc(x), gn(x), rtol=1e-6)


def test_param_count_formula(mesh24):
    n_in, n_out, k = 64, 32, 4
    axes = MeshAxes.from_mesh(mesh24)
    decls = phantom_decls(n_in, n_out, k, axes.tp)
    assert param_count(decls) == phantom_param_count(n_in, n_out, k,
                                                     axes.tp)


def test_paper_eqn8_compute_inequality():
    """Paper Eqn. 8: per-rank PP compute (n/p)^2 + kn beats TP's n^2/p
    exactly when k < (n/p)(1-1/p)."""
    n, p = 4096, 16
    k_max = (n / p) * (1 - 1 / p)

    def pp_compute(k):
        return (n / p) ** 2 + k * n

    tp_compute = n * n / p
    assert pp_compute(int(k_max) - 1) < tp_compute
    assert pp_compute(int(k_max) + 1) > tp_compute


def test_phantom_model_smaller_when_k_small():
    """PP params n^2/p + nk + pkn < TP's n^2 iff k < n(1-1/p)/(1+p)
    (paper §VI-B: smaller model => fewer iterations to fixed loss)."""
    n, p = 4096, 16
    k_bound = n * (1 - 1 / p) / (1 + p)
    dense = n * n + n
    assert phantom_param_count(n, n, int(k_bound) - 1, p) < dense
    assert phantom_param_count(n, n, int(k_bound) + 2, p) > dense
    # the paper's actual operating points are far below the bound
    for k in (2, 4, 16, 64):
        assert phantom_param_count(n, n, k, p) < dense / 2


def test_svd_init_error_decreases_with_k():
    from repro.core.lowrank import block_lowrank_error
    rng = np.random.default_rng(0)
    W = rng.standard_normal((64, 64)).astype(np.float32)
    errs = [block_lowrank_error(W, p=4, k=k) for k in (1, 4, 8, 16)]
    assert all(errs[i] > errs[i + 1] for i in range(len(errs) - 1)), errs
    assert block_lowrank_error(W, p=4, k=16) < 1e-5  # full rank: exact
