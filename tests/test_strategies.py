"""ProjectionStrategy API: every registered strategy must compute exactly
its own dense_equivalent() (forward AND gradients) on a (dp=2, tp=4)
mesh; the legacy ffn_impl/PhantomConfig shims must expand to identical
decls/params; and the Table II cost model must reproduce the historical
hand-derived closed forms by summing strategy flops()/comm_events()."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ModelConfig, PhantomConfig, ProjectionMap,
                                ProjectionSpec, get_config)
from repro.core.energy import comm_time_us, phantom_costs, tp_costs
from repro.parallel.axes import MeshAxes
from repro.parallel.params import materialize, param_count
from repro.parallel.strategies import (available_strategies, make_strategy,
                                       site_strategy)
from helpers import allclose, rand, resolved_param_specs, smap

KINDS = available_strategies()


def _spec(kind, k=3):
    return ProjectionSpec(kind=kind, k=k)


def _mk(mesh, kind, n_in, n_out, bias=True, k=3):
    axes = MeshAxes.from_mesh(mesh)
    st = make_strategy(_spec(kind, k), n_in, n_out, axes.tp, dp=axes.dp,
                       bias=bias)
    return st, axes


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert {"tensor_col", "tensor_row", "phantom",
            "lowrank_distill"} <= set(KINDS)
    with pytest.raises(KeyError):
        make_strategy(ProjectionSpec(kind="nope"), 8, 8, 2)


@pytest.mark.parametrize("kind", KINDS)
def test_param_count_matches_decls(mesh24, kind):
    st, _ = _mk(mesh24, kind, 64, 32)
    assert st.param_count() == param_count(st.decls())


# ---------------------------------------------------------------------------
# forward + gradient equivalence vs dense_equivalent()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", KINDS)
def test_forward_matches_dense_equivalent(mesh24, kind):
    n_in, n_out, B = 32, 48, 8
    st, axes = _mk(mesh24, kind, n_in, n_out)
    params = materialize(st.decls(), seed=1)
    x = rand(0, (B, n_in))
    f = smap(lambda p, xx: st.apply_shard(p, xx, axes), mesh24,
             (resolved_param_specs(st.decls(), mesh24),
              P(("data",), "model")), P(("data",), "model"))
    out = f(params, x)
    W, b = st.dense_equivalent(params)
    ref = x @ W + (0 if b is None else b)
    allclose(out, ref, rtol=1e-4, atol=1e-5, msg=f"kind={kind}")


@pytest.mark.parametrize("kind", KINDS)
def test_gradients_match_dense_equivalent(mesh24, kind):
    n, B = 32, 4
    st, axes = _mk(mesh24, kind, n, n, k=2)
    decls = st.decls()
    pspecs = resolved_param_specs(decls, mesh24)
    params = materialize(decls, seed=3)
    x = rand(2, (B, n))
    y = rand(3, (B, n))

    def sharded_loss(p, xx, yy):
        out = st.apply_shard(p, xx, axes)
        return jnp.sum((out - yy) ** 2)

    def _reduce(g, d):
        # dp-replicated grads psum over data; tp-replicated params (e.g.
        # the row bias) hold disjoint per-rank contributions -> psum tp
        g = jax.lax.psum(g, ("data",))
        entries = [e for ent in d.spec
                   for e in (ent if isinstance(ent, tuple) else (ent,))]
        if "tp" not in entries:
            g = jax.lax.psum(g, "model")
        return g

    from repro.parallel.params import is_decl
    gfn = smap(lambda p, xx, yy: jax.tree.map(
        _reduce, jax.grad(sharded_loss)(p, xx, yy), decls,
        is_leaf=lambda v: is_decl(v)),
        mesh24, (pspecs, P("data", "model"), P("data", "model")), pspecs)
    g_sharded = gfn(params, x, y)

    def dense_loss(p, xx, yy):
        W, b = st.dense_equivalent(p)
        out = xx @ W + (0 if b is None else b)
        return jnp.sum((out - yy) ** 2)

    g_dense = jax.grad(dense_loss)(params, x, y)
    for key in g_dense:
        allclose(g_sharded[key], g_dense[key], rtol=3e-3, atol=1e-4,
                 msg=f"grad {key} kind={kind}")


def test_lowrank_distill_init_reconstructs_teacher(mesh24):
    """Full-rank k: init_from_dense must reproduce the teacher exactly;
    truncated k monotonically improves with rank."""
    n, p = 32, 4
    axes = MeshAxes.from_mesh(mesh24)
    W = np.asarray(rand(7, (n, n)))
    st = make_strategy(ProjectionSpec(kind="lowrank_distill", k=n // p),
                       n, n, axes.tp, bias=True)
    params = st.init_from_dense(W)
    W_hat, b = st.dense_equivalent(params)
    allclose(W_hat, W, rtol=1e-4, atol=1e-5)
    errs = [make_strategy(ProjectionSpec(kind="lowrank_distill", k=k),
                          n, n, axes.tp).distill_error(W)
            for k in (1, 2, 4, 8)]
    assert all(a > b_ for a, b_ in zip(errs, errs[1:])), errs


# ---------------------------------------------------------------------------
# deprecation shims: legacy flags == explicit ProjectionSpecs
# ---------------------------------------------------------------------------

def test_ffn_impl_shim_decls_and_params_identical(mesh24):
    from repro.core.ffn import ffn_decls
    axes = MeshAxes.from_mesh(mesh24)
    shipped = get_config("paper-ffn-4k", smoke=True)
    # a legacy external caller's config: the deprecated ffn_impl=
    # selector with a bare PhantomConfig and NO explicit ProjectionMap
    # (shipped configs now carry explicit maps; the shim must keep
    # expanding to the same thing)
    old = shipped.replace(ffn_impl="phantom", projections=ProjectionMap())
    new = shipped.replace(
        projections=ProjectionMap(ffn_layer=ProjectionSpec(
            kind="phantom", k=shipped.phantom.k,
            variant=shipped.phantom.variant)))
    d_old, d_new = ffn_decls(old, axes), ffn_decls(new, axes)
    assert d_old == d_new
    # the shipped explicit-map config expands identically to the legacy
    # spelling it replaced
    assert ffn_decls(shipped, axes) == d_old
    p_old = materialize(d_old, seed=0)
    p_new = materialize(d_new, seed=0)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 p_old, p_new)
    # and the dense baseline == explicit tensor_col
    dense = shipped.replace(ffn_impl="dense", projections=ProjectionMap())
    explicit = shipped.replace(projections=ProjectionMap(
        ffn_layer=ProjectionSpec(kind="tensor_col")))
    assert ffn_decls(dense, axes) == ffn_decls(explicit, axes)


def test_apply_flags_shim_mlp_and_attn_decls_identical(mesh24):
    from repro.models.attention import attn_decls
    from repro.models.layers import mlp_decls
    axes = MeshAxes.from_mesh(mesh24)
    base = dict(name="t", family="dense", num_layers=2, d_model=32,
                num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=128,
                dtype="float32", mlp="swiglu")
    ph = ProjectionSpec(kind="phantom", k=2)
    old = ModelConfig(**base, phantom=PhantomConfig(
        k=2, apply_ffn=True, apply_attn_proj=True))
    new = ModelConfig(**base, phantom=PhantomConfig(
        k=2, apply_ffn=False, apply_attn_proj=False),
        projections=ProjectionMap(
            ffn_gate=ph, ffn_up=ph, ffn_down=ph,
            attn_q=ph, attn_k=ph, attn_v=ph, attn_o=ph))
    assert mlp_decls(old, axes, 32, 64) == mlp_decls(new, axes, 32, 64)
    assert attn_decls(old, axes) == attn_decls(new, axes)
    # per-site override wins over the legacy flag
    mixed = old.replace(projections=ProjectionMap(
        ffn_down=ProjectionSpec(kind="tensor_row")))
    d = mlp_decls(mixed, axes, 32, 64)
    assert "w" in d["down"] and "L" in d["gate"]


# ---------------------------------------------------------------------------
# mixed per-site strategies compute the same function as their dense
# equivalents composed
# ---------------------------------------------------------------------------

def test_mixed_mlp_matches_dense_composition(mesh24):
    from repro.models.layers import mlp_apply, mlp_decls
    axes = MeshAxes.from_mesh(mesh24)
    d, ff, B, S = 32, 64, 2, 8
    cfg = ModelConfig(
        name="t", family="dense", num_layers=1, d_model=d, num_heads=4,
        num_kv_heads=4, d_ff=ff, vocab_size=128, dtype="float32",
        mlp="swiglu",
        projections=ProjectionMap(
            ffn_gate=ProjectionSpec(kind="phantom", k=2),
            ffn_up=ProjectionSpec(kind="tensor"),      # site default (col)
            ffn_down=ProjectionSpec(kind="lowrank_distill", k=2)))
    decls = mlp_decls(cfg, axes, d, ff)
    assert "L" in decls["gate"] and "w" in decls["up"] \
        and "L" in decls["down"]
    params = materialize(decls, seed=4)
    x = rand(5, (B, S, d), scale=0.5)

    fn = smap(lambda p, xx: mlp_apply(cfg, "fp", p, xx, axes), mesh24,
              (resolved_param_specs(decls, mesh24), P("data", None, "model")),
              P("data", None, "model"))
    out = fn(params, x)

    from repro.models.layers import mlp_strategies
    sts = mlp_strategies(cfg, axes, d, ff)
    Wg, _ = sts["gate"].dense_equivalent(params["gate"])
    Wu, _ = sts["up"].dense_equivalent(params["up"])
    Wd, _ = sts["down"].dense_equivalent(params["down"])
    ref = (jax.nn.silu(x @ Wg) * (x @ Wu)) @ Wd
    allclose(out, ref, rtol=3e-4, atol=3e-5)


def test_moe_phantom_experts_match_dense_reference(mesh24):
    """Phantom-factorized experts (tensor partition) compute the dense
    MoE whose per-expert weights are each expert's dense_equivalent."""
    from repro.models import moe as M
    from test_moe import _cfg, _dense_moe_ref
    axes = MeshAxes.from_mesh(mesh24)
    cfg = _cfg(E=4, top_k=2, partition="tensor", layout="fp")
    cfg = cfg.replace(projections=ProjectionMap(
        moe_experts=ProjectionSpec(kind="phantom", k=2)))
    decls = M.moe_decls(cfg, axes)
    assert "L" in decls["w_up"], "phantom expert decls expected"
    params = materialize(decls, 5)
    B, S = 2, 16
    x = rand(0, (B, S, cfg.d_model), scale=0.5)

    def f(p, xx):
        y, _aux = M.moe_apply(cfg, "fp", p, xx, axes)
        return y

    fn = smap(f, mesh24, (resolved_param_specs(decls, mesh24),
                          P("data", None, "model")),
              P("data", None, "model"))
    out = fn(params, x)

    # assemble dense per-expert weights from the phantom factors
    st = make_strategy(ProjectionSpec(kind="phantom", k=2), cfg.d_model,
                       cfg.moe.d_ff_expert, axes.tp, bias=False)
    std = make_strategy(ProjectionSpec(kind="phantom", k=2),
                        cfg.moe.d_ff_expert, cfg.d_model, axes.tp,
                        bias=False)
    E = cfg.moe.num_experts

    def densify(stx, tree):
        return jnp.stack([stx.dense_equivalent(
            jax.tree.map(lambda a: a[e], tree))[0] for e in range(E)])

    dense_params = {
        "router": params["router"],
        "w_gate": {"w": densify(st, params["w_gate"])},
        "w_up": {"w": densify(st, params["w_up"])},
        "w_down": {"w": densify(std, params["w_down"])},
    }
    ref = _dense_moe_ref(cfg, dense_params, x)
    allclose(out, ref, rtol=3e-3, atol=3e-4)


# ---------------------------------------------------------------------------
# cost accounting: strategy sums == the historical hand-derived formulas
# ---------------------------------------------------------------------------

def _old_tp_costs(n, p, L, batch, peak, fits=None):
    flops_total = 6.0 * n * n * batch * L
    alpha = flops_total / p / peak
    beta = (comm_time_us("all_gather", (n / p) * batch, p, fits)
            + comm_time_us("reduce_scatter", (n / p) * batch, p, fits)) \
        * L * 1e-6
    return alpha, beta


def _old_pp_costs(n, p, L, k, batch, peak, fits=None):
    per_rank = (n / p) ** 2 + k * n
    alpha = 6.0 * per_rank * batch * L / peak
    beta = (comm_time_us("all_gather", k * batch, p, fits)
            + comm_time_us("reduce_scatter", k * batch, p, fits)) \
        * L * 1e-6
    return alpha, beta


def test_strategy_costs_match_hand_formulas_paper_ffn():
    """Acceptance criterion: Table II predictions (AG n/p-wide for TP, AG
    k-wide for phantom) summed from strategy comm_events()/flops() equal
    the previous hand-derived formulas for the paper-FFN configs."""
    peak = 197e12
    for arch in ("paper-ffn-4k", "paper-ffn-16k", "paper-ffn-64k",
                 "paper-ffn-131k", "paper-ffn-262k"):
        cfg = get_config(arch)
        n, L, k = cfg.ffn_width, cfg.num_layers, cfg.phantom.k
        for p in (2, 8, 64, 256):
            batch = 1024
            a, b = tp_costs(n, p, L, batch, peak)
            a_ref, b_ref = _old_tp_costs(n, p, L, batch, peak)
            np.testing.assert_allclose(a, a_ref, rtol=1e-12)
            np.testing.assert_allclose(b, b_ref, rtol=1e-12)
            a, b = phantom_costs(n, p, L, k, batch, peak)
            a_ref, b_ref = _old_pp_costs(n, p, L, k, batch, peak)
            np.testing.assert_allclose(a, a_ref, rtol=1e-12)
            np.testing.assert_allclose(b, b_ref, rtol=1e-12)


def test_comm_events_are_table2_schedule():
    """TP: AG of (n/p)*batch floats fwd; phantom: AG of k*batch fwd —
    straight from the strategy objects."""
    n, p, k, batch = 4096, 16, 8, 64
    tp_st = make_strategy(ProjectionSpec(kind="tensor_col"), n, n, p)
    pp_st = make_strategy(ProjectionSpec(kind="phantom", k=k), n, n, p)
    (ag, rs) = tp_st.comm_events(batch)
    assert (ag.collective, ag.phase, ag.m_floats) == \
        ("all_gather", "fwd", (n / p) * batch)
    assert (rs.collective, rs.phase) == ("reduce_scatter", "bwd")
    (ag, rs) = pp_st.comm_events(batch)
    assert (ag.collective, ag.phase, ag.m_floats) == \
        ("all_gather", "fwd", k * batch)
    assert rs.m_floats == k * batch


def test_phantom_flops_below_tensor_in_paper_regime():
    """Paper Eqn. 8 via the strategy API: phantom wins per-rank compute
    exactly when k < (n/p)(1 - 1/p)."""
    n, p = 4096, 16
    k_max = (n / p) * (1 - 1 / p)
    tp_st = make_strategy(ProjectionSpec(kind="tensor_col"), n, n, p,
                          bias=False)
    lo = make_strategy(ProjectionSpec(kind="phantom", k=int(k_max) - 1),
                       n, n, p, bias=False)
    hi = make_strategy(ProjectionSpec(kind="phantom", k=int(k_max) + 2),
                       n, n, p, bias=False)
    assert lo.flops(1) < tp_st.flops(1) < hi.flops(1)


def test_site_strategy_guard_falls_back_to_dense():
    """Indivisible dims force the site's natural dense strategy."""
    cfg = ModelConfig(
        name="t", family="dense", num_layers=1, d_model=30, num_heads=3,
        num_kv_heads=3, d_ff=60, vocab_size=128,
        phantom=PhantomConfig(k=2, apply_ffn=True))
    st = site_strategy(cfg, "ffn_up", 30, 60, 4)   # 30 % 4 != 0
    assert st.kind == "tensor_col"
    st = site_strategy(cfg, "ffn_down", 60, 30, 4)
    assert st.kind == "tensor_row"
