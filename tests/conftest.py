"""Test harness config.

Multi-device correctness tests (shard_map collectives, TP-vs-phantom
equivalence, elastic checkpointing) need a small local mesh, so we ask the
CPU backend for 8 virtual devices — the standard JAX testing pattern.
NOTE: this is deliberately NOT the dry-run's 512 (launch/dryrun.py sets
that itself, in its own process, before importing jax).
"""
import os

flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in flags:
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 " + flags)

import jax  # noqa: E402  (must import after the flag)
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh24():
    """(data=2, model=4) mesh."""
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(2, 4)


@pytest.fixture(scope="session")
def mesh18():
    """(data=1, model=8) mesh."""
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(1, 8)


@pytest.fixture(scope="session")
def mesh42():
    """(data=4, model=2) mesh."""
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(4, 2)


@pytest.fixture(scope="session")
def mesh14():
    """(data=1, model=4) mesh — same tp as mesh24, half the dp (elastic
    rescale changes dp only: the phantom model class is tp-dependent)."""
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(1, 4)


@pytest.fixture(scope="session")
def mesh222():
    """(pipe=2, data=2, model=2) mesh — the pipeline-parallel testbed."""
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(2, 2, 2)


@pytest.fixture(scope="session")
def mesh124():
    """(pipe=4, data=1, model=2) mesh — deep-pipeline testbed."""
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(1, 2, 4)


@pytest.fixture(scope="session")
def mesh12():
    """(data=1, model=2) mesh — the pp-mesh equivalence reference."""
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(1, 2)


@pytest.fixture(scope="session")
def compiled_step_cache():
    """Session-scoped memo of jit-compiled step/probe builders.

    Compiling a shard_map step dominates test wall time, and the
    property-based suites re-draw the same few configurations many
    times; ``cache.build(maker, cfg, mesh, *key_extras)`` calls
    ``maker(cfg, mesh, *key_extras)`` once per distinct (maker, cfg,
    mesh axes, extras) and replays the compiled result afterwards.
    ``ModelConfig`` is frozen/hashable, so the config IS the key.
    """
    class _Cache(dict):
        def build(self, maker, cfg, mesh, *extras):
            key = (maker.__module__, maker.__qualname__, cfg,
                   tuple(zip(mesh.axis_names, mesh.devices.shape)), extras)
            if key not in self:
                self[key] = maker(cfg, mesh, *extras)
            return self[key]

    return _Cache()
