"""Test harness config.

Multi-device correctness tests (shard_map collectives, TP-vs-phantom
equivalence, elastic checkpointing) need a small local mesh, so we ask the
CPU backend for 8 virtual devices — the standard JAX testing pattern.
NOTE: this is deliberately NOT the dry-run's 512 (launch/dryrun.py sets
that itself, in its own process, before importing jax).
"""
import os

flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in flags:
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 " + flags)

import jax  # noqa: E402  (must import after the flag)
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh24():
    """(data=2, model=4) mesh."""
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(2, 4)


@pytest.fixture(scope="session")
def mesh18():
    """(data=1, model=8) mesh."""
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(1, 8)


@pytest.fixture(scope="session")
def mesh42():
    """(data=4, model=2) mesh."""
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(4, 2)


@pytest.fixture(scope="session")
def mesh14():
    """(data=1, model=4) mesh — same tp as mesh24, half the dp (elastic
    rescale changes dp only: the phantom model class is tp-dependent)."""
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(1, 4)
