"""Gradient compression (phantom-for-gradients, PowerSGD-style): exactness
on low-rank grads, error-feedback convergence, wire-bytes accounting."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.optim.compress import (compress_grad, compressed_dp_psum,
                                  init_compress_state)
from repro.parallel.axes import MeshAxes
from helpers import allclose, rand, smap


def test_exact_when_lowrank(mesh24):
    """A rank-2 gradient is reproduced exactly by rank-4 compression."""
    n, m, r = 32, 16, 4
    u = rand(0, (n, 2))
    v = rand(1, (2, m))
    g = u @ v                      # same on all dp ranks

    def f(gg, q):
        approx, qn = compress_grad(gg, q, ("data",))
        return approx

    q0 = rand(2, (m, r))
    fn = smap(f, mesh24, (P(None, None), P(None, None)), P(None, None))
    # one subspace iteration of a warm q needs a couple of rounds to
    # capture the exact column space; iterate
    q = q0
    for _ in range(3):
        def f2(gg, qq):
            return compress_grad(gg, qq, ("data",))[1]
        q = smap(f2, mesh24, (P(None, None), P(None, None)),
                 P(None, None))(g, q)
    approx = fn(g, q)
    allclose(approx, g, rtol=1e-3, atol=1e-4)


def test_error_feedback_identity(mesh24):
    """Error feedback guarantees EXACTLY: sum(delivered) + err_T = T * g
    (each step: delivered = g + err_prev - err_new).  This is the
    convergence mechanism — nothing is ever lost, only delayed."""
    g_true = rand(3, (16, 8))
    params = {"w": jnp.zeros((16, 8))}
    q_state, err_state = init_compress_state(params, rank=1)
    axes = MeshAxes.from_mesh(mesh24)

    total = jnp.zeros_like(g_true)
    q, err = q_state["w"], err_state["w"]

    def step(qq, ee):
        def f(gg, q_, e_):
            red, qn, en = compressed_dp_psum(
                {"w": gg}, {"w": q_}, {"w": e_}, axes, rank=1)
            return red["w"], qn["w"], en["w"]
        return smap(f, mesh24,
                    (P(None, None), P(None, None), P(None, None)),
                    (P(None, None), P(None, None), P(None, None)))(
                        g_true, qq, ee)

    T = 30
    for _ in range(T):
        red, q, err = step(q, err)
        total = total + red
    allclose(total + err, T * g_true, rtol=1e-3, atol=1e-3)
    # and the rank-1 subspace captures a nontrivial share each step
    assert float(jnp.linalg.norm(err)) < float(
        jnp.linalg.norm(T * g_true))


def test_small_leaves_pass_through(mesh24):
    axes = MeshAxes.from_mesh(mesh24)
    g = {"b": rand(5, (7,))}
    q, e = init_compress_state({"b": jnp.zeros((7,))}, rank=4)

    def f(gg, qq, ee):
        red, _, _ = compressed_dp_psum(gg, qq, ee, axes, rank=4)
        return red

    fn = smap(f, mesh24, (P(None), {"b": P(None)}, {"b": P(None)}),
              {"b": P(None)})
    red = fn(g, q, e)
    allclose(red["b"], g["b"], rtol=1e-6)  # pmean of identical copies
