"""MoE dispatch correctness: the capacity-indexed take/scatter dispatch
must equal a dense (all-experts) reference when capacity is ample, and
both expert partitionings must agree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig, PhantomConfig
from repro.models import moe as M
from repro.parallel.axes import MeshAxes
from repro.parallel.params import materialize
from helpers import allclose, rand, resolved_param_specs, smap


def _cfg(E, top_k, partition, d=32, ff=16, cf=8.0, layout="fp"):
    # the residual layout is derived from phantom usage: fp iff phantom on
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=d, num_heads=4,
        num_kv_heads=4, d_ff=ff, vocab_size=128, dtype="float32",
        moe=MoEConfig(num_experts=E, top_k=top_k, d_ff_expert=ff,
                      partition=partition, capacity_factor=cf),
        phantom=PhantomConfig(apply_ffn=False,
                              apply_attn_proj=(layout == "fp")),
        mlp="swiglu")


def _dense_moe_ref(cfg, params, x):
    """All-experts reference: softmax top-k gating, no capacity drops."""
    m = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ params["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gate_vals, exp_idx = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    wg, wu, wd = (params["w_gate"]["w"], params["w_up"]["w"],
                  params["w_down"]["w"])
    # every expert on every token
    h = jnp.einsum("td,edf->tef", xf, wg)
    h = jax.nn.silu(h) * jnp.einsum("td,edf->tef", xf, wu)
    y_all = jnp.einsum("tef,efd->ted", h, wd)
    y = jnp.zeros_like(xf)
    for kk in range(m.top_k):
        y = y + (jnp.take_along_axis(
            y_all, exp_idx[:, kk][:, None, None], axis=1)[:, 0]
            * gate_vals[:, kk:kk + 1])
    return y.reshape(B, S, d)


@pytest.mark.parametrize("partition,layout", [("expert", "fp"),
                                              ("expert", "sp"),
                                              ("expert", "rep"),
                                              ("tensor", "sp"),
                                              ("tensor", "rep")])
def test_moe_matches_dense_reference(mesh24, partition, layout):
    cfg = _cfg(E=8, top_k=2, partition=partition, layout=layout)
    axes = MeshAxes.from_mesh(mesh24)
    decls = M.moe_decls(cfg, axes)
    params = materialize(decls, 5)
    B, S = 2, 16
    x = rand(0, (B, S, cfg.d_model), scale=0.5)
    xspec = {"fp": P("data", None, "model"),
             "sp": P("data", "model", None),
             "rep": P("data", None, None)}[layout]

    def f(p, xx):
        y, aux = M.moe_apply(cfg, layout, p, xx, axes)
        return y

    fn = smap(f, mesh24, (resolved_param_specs(decls, mesh24), xspec),
              xspec)
    out = fn(params, x)
    ref = _dense_moe_ref(cfg, params, x)
    allclose(out, ref, rtol=3e-3, atol=3e-4,
             msg=f"partition={partition}")


def test_route_capacity_is_respected():
    T, E, K, C = 64, 4, 2, 8
    logits = rand(1, (T, E))
    disp_tok, disp_ok, gates, combine_slot = M.route(logits, K, C)
    assert disp_tok.shape == (E, C)
    # every kept slot points at a real token
    assert np.asarray(disp_tok).max() < T
    # each expert serves at most C tokens (by construction) and each
    # token appears at most once per expert slot
    used = np.asarray(combine_slot)
    used = used[used >= 0]
    assert len(np.unique(used)) == len(used)


def test_route_drops_overflow():
    T, E, K = 32, 2, 1
    C = 4  # far less than T*K/E = 16 -> drops must happen
    logits = jnp.zeros((T, E)).at[:, 0].set(10.0)   # all to expert 0
    _dt, disp_ok, _g, combine_slot = M.route(logits, K, C)
    assert int(disp_ok.sum()) == C   # capacity enforced
    kept = int((np.asarray(combine_slot) >= 0).sum())
    assert kept == C


def test_aux_loss_balanced_lower():
    T, E = 512, 8
    balanced = rand(2, (T, E), scale=0.01)
    skewed = jnp.zeros((T, E)).at[:, 0].set(10.0)
    assert float(M._aux_loss(balanced, E)) < float(M._aux_loss(skewed, E))
