"""Golden-value fixture for the energy model's per-strategy accounts.

``compute()`` evaluates every pinned quantity — strategy ``flops()`` /
``param_count()`` / ``comm_events()`` (Table II), the tp/phantom closed
forms, Eqn. 26 comm times including the new single-hop
``collective_permute`` stage-boundary pricing, the 1F1B schedule
geometry, and the executed-SPMD pipeline step prediction —
from the live code.  ``tests/fixtures/golden_costs.json`` stores the
values this PR shipped with; ``test_golden_costs.py`` fails on ANY
drift, so an energy-model refactor cannot silently change predictions.

Regenerate DELIBERATELY (after verifying the new numbers are intended):

    PYTHONPATH=src python tests/make_golden_costs.py
"""
from __future__ import annotations

import json
import os

FIXTURE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "fixtures", "golden_costs.json")


def compute() -> dict:
    from repro.configs.base import (ModelConfig, PhantomConfig,
                                    PipelineConfig, ProjectionSpec)
    from repro.core.energy import (comm_time_us, phantom_costs,
                                   pipeline_p2p_time_us, tp_costs)
    from repro.parallel.strategies import make_strategy
    from repro.telemetry.predict import pipeline_ffn_step_prediction
    from repro.train.pipeline import PipelineSchedule

    n, tp, batch = 512, 4, 32
    out = {"strategies": {}, "closed_forms": {}, "comm_time_us": {},
           "schedule": {}, "pipeline_prediction": {},
           "fused_kernel_prediction": {}}

    for kind, k in (("tensor_col", 0), ("tensor_row", 0),
                    ("phantom", 8), ("lowrank_distill", 4)):
        spec = ProjectionSpec(kind=kind, k=k or 64)
        st = make_strategy(spec, n, n, tp, bias=True)
        out["strategies"][f"{kind}_k{k}"] = {
            "n": n, "tp": tp, "batch": batch, "k": k,
            "flops": st.flops(batch),
            "param_count": st.param_count(),
            "comm_events": [[ev.collective, ev.m_floats, ev.phase]
                            for ev in st.comm_events(batch)],
        }

    a_t, b_t = tp_costs(n, tp, 2, batch, 197e12)
    a_p, b_p = phantom_costs(n, tp, 2, 8, batch, 197e12)
    out["closed_forms"] = {
        "tp_costs_n512_p4_L2_b32": [a_t, b_t],
        "phantom_costs_n512_p4_L2_k8_b32": [a_p, b_p],
    }

    for coll in ("broadcast", "all_reduce", "all_gather",
                 "reduce_scatter", "collective_permute"):
        out["comm_time_us"][f"{coll}_m4096_p4"] = comm_time_us(coll,
                                                               4096.0, 4)

    sched = PipelineSchedule(stages=4, microbatches=8)
    out["schedule"] = {
        "stages": 4, "microbatches": 8,
        "num_ticks": sched.num_ticks,
        "bubble_fraction": sched.bubble_fraction,
        "warmup": [sched.warmup(s) for s in range(4)],
        "max_in_flight": [sched.max_in_flight(s) for s in range(4)],
        "table_stage0": sched.table(0)[:8],
        "p2p_events_ideal": len(sched.p2p_events(1.0)),
        "p2p_events_executed": len(sched.p2p_events(1.0, executed=True)),
        "p2p_time_us_m2048_ideal": pipeline_p2p_time_us(sched, 2048.0),
        "p2p_time_us_m2048_executed": pipeline_p2p_time_us(
            sched, 2048.0, executed=True),
        "stage_bounds_L10": sched.stage_bounds(10),
    }

    for impl, k in (("dense", 8), ("phantom", 8)):
        cfg = ModelConfig(name=f"golden-{impl}", family="ffn",
                          num_layers=4, d_model=256, ffn_width=256,
                          ffn_depth=4, ffn_impl=impl, mlp="relu",
                          phantom=PhantomConfig(k=k),
                          pipeline=PipelineConfig(stages=2),
                          microbatches=4)
        pred = pipeline_ffn_step_prediction(cfg, 2, 2, 2, 32,
                                            executed=True)
        out["pipeline_prediction"][impl] = {
            key: pred[key] for key in (
                "flops_per_device", "collective_wire_bytes_per_device",
                "boundary_wire_bytes_per_device", "collective_m_floats",
                "comm_us", "energy_j_per_iter", "ticks",
                "bubble_fraction")}

    # fused Pallas kernel backend: the prediction must be IDENTICAL to
    # the XLA path on every shared key (the kernel fuses GEMMs, never
    # collectives) — pinning both proves zero drift between backends.
    from repro.configs.base import phantom_projection_map
    from repro.telemetry.predict import (ffn_step_prediction,
                                         fused_ffn_step_prediction,
                                         fused_kernel_step_events)
    for backend in ("xla", "pallas"):
        cfg = ModelConfig(name=f"golden-kernel-{backend}", family="ffn",
                          num_layers=2, d_model=512, ffn_width=512,
                          ffn_depth=2, mlp="relu",
                          phantom=PhantomConfig(k=8),
                          projections=phantom_projection_map(
                              8, ffn_layer=True, kernel_backend=backend))
        pred = fused_ffn_step_prediction(cfg, 4, 32)
        base = ffn_step_prediction(cfg, 4, 32)
        out["fused_kernel_prediction"][backend] = {
            "kernel_backend": pred["kernel_backend"],
            "hbm_bytes_saved_per_device":
                pred["hbm_bytes_saved_per_device"],
            "flops_per_device": pred["flops_per_device"],
            "collective_wire_bytes_per_device":
                pred["collective_wire_bytes_per_device"],
            "collective_m_floats": pred["collective_m_floats"],
            "energy_j_per_iter": pred["energy_j_per_iter"],
            "drift_vs_xla_builder": max(
                abs(pred[key] - base[key]) for key in (
                    "flops_per_device",
                    "collective_wire_bytes_per_device",
                    "collective_m_floats", "energy_j_per_iter")),
            "events": [[ev.collective, ev.m_floats, ev.phase, reps]
                       for ev, reps in
                       fused_kernel_step_events(cfg, 4, 32)],
        }
    return out


def main():
    os.makedirs(os.path.dirname(FIXTURE), exist_ok=True)
    with open(FIXTURE, "w") as f:
        json.dump(compute(), f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {FIXTURE}")


if __name__ == "__main__":
    main()
