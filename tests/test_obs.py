"""Observability: tracer golden schema, metrics export, watchdog
trip/no-trip fixtures, the obs CLI, and the bench-regression gate.

The tracer tests run on a manually-advanced clock so span ids AND
timestamps are deterministic — the golden assertions pin the exact
Chrome-trace-event schema Perfetto loads (docs/observability.md)."""
import json
import os
import subprocess
import sys
import threading

import pytest

from repro.obs import (EnergyDriftWatchdog, MetricsRegistry,
                       SNAPSHOT_SCHEMA, TRACE_SCHEMA, Tracer, get_tracer,
                       load_trace, set_tracer, span_events, use_tracer)
from repro.telemetry import Ledger, LedgerEntry

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class ManualClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_trace_golden_schema():
    """Two identical schedules on a manual clock produce byte-identical
    Chrome-trace JSON with stable span ids."""
    def build():
        clk = ManualClock()
        tr = Tracer(clock=clk, meta={"run": "test"})
        with tr.span("plan/calibrate", cat="plan", source="paper"):
            clk.advance(0.25)
        sp = tr.begin("train/run", cat="train")
        clk.advance(0.5)
        with tr.span("train/step", cat="train", step=0):
            clk.advance(0.125)
        tr.instant("fault/straggler", cat="fault", step=0)
        tr.end(sp.annotate(final_step=1))
        return tr.to_chrome()

    doc = build()
    assert json.dumps(doc, sort_keys=True) == \
        json.dumps(build(), sort_keys=True)

    assert doc["otherData"]["schema"] == TRACE_SCHEMA
    assert doc["otherData"]["run"] == "test"
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {"process_name", "thread_name"} <= {e["name"] for e in meta}

    spans = {e["name"]: e for e in evs if e["ph"] == "X"}
    cal = spans["plan/calibrate"]
    assert cal["cat"] == "plan" and cal["pid"] == 0 and cal["tid"] == 0
    assert cal["ts"] == 0.0 and cal["dur"] == 250_000.0
    assert cal["args"]["span_id"] == "s000000"
    assert cal["args"]["source"] == "paper"
    # ids assigned at BEGIN time: train/run opened before train/step
    assert spans["train/run"]["args"]["span_id"] == "s000001"
    assert spans["train/step"]["args"]["span_id"] == "s000002"
    assert spans["train/run"]["args"]["final_step"] == 1
    assert spans["train/run"]["dur"] == 625_000.0

    inst = [e for e in evs if e["ph"] == "i"]
    assert len(inst) == 1 and inst[0]["s"] == "t"
    assert inst[0]["name"] == "fault/straggler"


def test_unclosed_span_survives_crash_dump():
    clk = ManualClock()
    tr = Tracer(clock=clk)
    tr.begin("train/run", cat="train")
    clk.advance(1.0)
    evs = span_events(tr.to_chrome())
    assert len(evs) == 1
    assert evs[0]["args"]["unclosed"] is True
    assert evs[0]["dur"] == 1_000_000.0


def test_null_tracer_is_free_noop():
    tr = Tracer(enabled=False)
    sp = tr.begin("x")
    sp.annotate(a=1).link_ledger(None)
    tr.end(sp)
    tr.instant("y")
    with tr.span("z"):
        pass
    assert len(tr) == 0
    # the module default is disabled
    assert get_tracer().enabled is False or get_tracer() is not None


def test_set_tracer_restores_previous():
    tr = Tracer()
    prev = set_tracer(tr)
    try:
        assert get_tracer() is tr
    finally:
        set_tracer(prev)
    assert get_tracer() is not tr


def test_span_links_ledger_entry():
    tr = Tracer()
    entry = LedgerEntry(
        name="train_smoke_phantom", suite="train", kind="train",
        measured={"wall_us_median": 123.0, "total_s": 0.5, "calls": 4},
        predicted={"energy_j_per_iter": 1.5})
    with tr.span("train/run", cat="train") as sp:
        sp.link_ledger(entry)
    ev = span_events(tr.to_chrome())[0]
    link = ev["args"]["ledger"]
    assert link["entry"] == "train_smoke_phantom"
    assert link["wall_us_median"] == 123.0
    assert link["predicted_energy_j_per_iter"] == 1.5


def test_worker_thread_gets_own_tid():
    tr = Tracer()
    with tr.span("main/work"):
        t = threading.Thread(
            target=lambda: tr.end(tr.begin("ckpt/save", cat="ckpt")))
        t.start()
        t.join()
    evs = span_events(tr.to_chrome())
    tids = {e["name"]: e["tid"] for e in evs}
    assert tids["main/work"] == 0
    assert tids["ckpt/save"] == 1
    names = {e["args"]["name"] for e in tr.to_chrome()["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names == {"main", "worker-1"}


def test_trace_write_load_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("serve/prefill", cat="serve"):
        pass
    p = tr.write(str(tmp_path / "trace.json"))
    doc = load_trace(p)
    assert span_events(doc, cat="serve", name_prefix="serve/")
    with pytest.raises(ValueError):
        bad = tmp_path / "bad.json"
        bad.write_text("[]")
        load_trace(str(bad))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_prometheus_exposition_golden():
    reg = MetricsRegistry()
    c = reg.counter("train_steps_total", "steps run")
    c.inc(3, suite="elastic")
    reg.gauge("pipeline_bubble_fraction").set(0.25, stages="2")
    h = reg.histogram("step_seconds", "step wall", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    assert reg.to_prometheus() == (
        "# TYPE pipeline_bubble_fraction gauge\n"
        'pipeline_bubble_fraction{stages="2"} 0.25\n'
        "# HELP step_seconds step wall\n"
        "# TYPE step_seconds histogram\n"
        'step_seconds_bucket{le="0.1"} 1\n'
        'step_seconds_bucket{le="1"} 2\n'
        'step_seconds_bucket{le="+Inf"} 3\n'
        "step_seconds_sum 5.55\n"
        "step_seconds_count 3\n"
        "# HELP train_steps_total steps run\n"
        "# TYPE train_steps_total counter\n"
        'train_steps_total{suite="elastic"} 3\n')


def test_registration_idempotent_and_kind_checked():
    reg = MetricsRegistry()
    a = reg.counter("x_total")
    assert reg.counter("x_total") is a
    with pytest.raises(TypeError):
        reg.gauge("x_total")
    with pytest.raises(ValueError):
        a.inc(-1)
    with pytest.raises(ValueError):
        reg.histogram("h", buckets=())


def test_jsonl_snapshot_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("serve_prefill_tokens_total").inc(64, arch="ffn")
    reg.histogram("ttft_ms", buckets=(1, 10)).observe(3.0, arch="ffn")
    p = str(tmp_path / "metrics.jsonl")
    reg.write(p, meta={"run": "t"})
    reg.write(p)     # appends a second snapshot
    lines = [json.loads(ln) for ln in open(p)]
    assert len(lines) == 2
    snap = lines[0]
    assert snap["schema"] == SNAPSHOT_SCHEMA
    assert snap["meta"]["run"] == "t"
    m = snap["metrics"]["serve_prefill_tokens_total"]
    assert m["kind"] == "counter"
    assert m["values"]['{arch="ffn"}'] == 64
    hist = snap["metrics"]["ttft_ms"]["values"]['{arch="ffn"}']
    assert hist["count"] == 1 and hist["buckets"]["10"] == 1


def test_metrics_concurrent_updates_are_exact():
    """The checkpoint writer thread and the step loop both record; the
    registry lock must not drop increments."""
    reg = MetricsRegistry()
    c = reg.counter("n_total")
    h = reg.histogram("v", buckets=(0.5,))

    def work():
        for _ in range(1000):
            c.inc()
            h.observe(0.1)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value() == 8000
    assert h.count() == 8000


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_silent_on_clean_run():
    wd = EnergyDriftWatchdog(predicted_s=0.1)
    for step in range(50):
        assert wd.observe(step, 0.1 + 0.01 * (step % 3)) is None
    assert wd.trips == []
    assert wd.summary()["observations"] == 50


def test_watchdog_spike_trips_and_records_anomaly():
    ledger = Ledger(run="t")
    wd = EnergyDriftWatchdog(predicted_s=0.1, ledger=ledger,
                             name="wd", profile_dir="/tmp/none")
    for step in range(5):
        wd.observe(step, 0.1)
    ev = wd.observe(5, 0.65)            # ratio 6.5 >= spike_factor 3
    assert ev is not None and ev.kind == "spike"
    assert ev.ratio == pytest.approx(6.5)
    assert wd.capture_pending()
    rows = [e for e in ledger.entries if e.kind == "anomaly"]
    assert len(rows) == 1
    assert rows[0].suite == "obs"
    assert rows[0].extra["event"] == "watchdog_spike"
    assert rows[0].measured["step"] == 5


def test_watchdog_drift_trips_on_window_mean():
    wd = EnergyDriftWatchdog(predicted_s=0.1, window=4)
    for step in range(8):
        wd.observe(step, 0.1)
    # creep up: each ratio 2.6 is under the 3.0 spike threshold, but
    # the trailing-window mean leaves the (0.5, 2.0) band
    kinds = []
    for step in range(8, 16):
        ev = wd.observe(step, 0.26)
        if ev:
            kinds.append(ev.kind)
    assert kinds == ["drift"]           # cooldown mutes the rest


def test_watchdog_cooldown_mutes_repeats():
    wd = EnergyDriftWatchdog(predicted_s=0.1, cooldown=5)
    trips = sum(1 for step in range(20)
                if wd.observe(step, 1.0) is not None)
    # 20 spiking observations, cooldown 5 -> at most every 6th trips
    assert 1 <= trips <= 4
    assert len(wd.trips) == trips


def test_watchdog_self_baseline_when_no_prediction():
    wd = EnergyDriftWatchdog(min_samples=3)
    for step in range(3):
        assert wd.observe(step, 0.2) is None     # collecting baseline
    assert wd.reference_s() == pytest.approx(0.2)
    ev = wd.observe(3, 1.0)                      # 5x the baseline
    assert ev is not None and ev.kind == "spike"


def test_watchdog_capture_oneshot(monkeypatch, tmp_path):
    import jax
    calls = []
    monkeypatch.setattr(jax.profiler, "start_trace",
                        lambda d: calls.append(("start", d)))
    monkeypatch.setattr(jax.profiler, "stop_trace",
                        lambda: calls.append(("stop",)))
    wd = EnergyDriftWatchdog(predicted_s=0.1,
                             profile_dir=str(tmp_path / "prof"))
    assert wd.capture(lambda: 7) == 7            # not armed: plain call
    assert calls == []
    for step in range(5):
        wd.observe(step, 0.1)
    wd.observe(5, 1.0)                           # trip arms the capture
    assert wd.capture_pending()
    assert wd.capture(lambda x: x + 1, 1) == 2
    assert calls == [("start", str(tmp_path / "prof")), ("stop",)]
    assert not wd.capture_pending()              # one-shot
    assert wd.captures == [str(tmp_path / "prof")]


# ---------------------------------------------------------------------------
# the obs CLI
# ---------------------------------------------------------------------------

def _write_recovery_fixture(tmp_path, *, replan_s=0.2, restore_s=0.3,
                            compile_s=1.5, span_scale=1.0):
    """A trace + report pair whose recovery views agree up to scale."""
    clk = ManualClock()
    tr = Tracer(clock=clk)
    for name, secs in (("elastic/compile", compile_s),
                       ("elastic/replan", replan_s),
                       ("elastic/restore", restore_s)):
        with tr.span(name, cat="elastic"):
            clk.advance(secs * span_scale)
    trace = str(tmp_path / "trace.json")
    tr.write(trace)
    report = str(tmp_path / "report.json")
    with open(report, "w") as f:
        json.dump({"entries": [
            {"name": "elastic_run", "kind": "elastic",
             "extra": {"recovery": {
                 "schema": "recovery-account/v1",
                 "replan_s": replan_s, "restore_s": restore_s,
                 "compile_s": compile_s}}}]}, f)
    return trace, report


def test_obs_cli_verify_recovery(tmp_path, capsys):
    from repro.launch.obs import main as obs_main
    trace, report = _write_recovery_fixture(tmp_path)
    assert obs_main(["verify-recovery", "--trace", trace,
                     "--report", report]) == 0
    assert "OK" in capsys.readouterr().out

    trace, report = _write_recovery_fixture(tmp_path, span_scale=2.0)
    assert obs_main(["verify-recovery", "--trace", trace,
                     "--report", report]) == 1
    assert "FAIL" in capsys.readouterr().err


def test_obs_cli_verify_recovery_clean_run(tmp_path, capsys):
    """compile-only views (no recovery) must still reconcile."""
    from repro.launch.obs import main as obs_main
    trace, report = _write_recovery_fixture(
        tmp_path, replan_s=0.0, restore_s=0.0, compile_s=0.8)
    assert obs_main(["verify-recovery", "--trace", trace,
                     "--report", report]) == 0


def test_obs_cli_summary_and_metrics(tmp_path, capsys):
    from repro.launch.obs import main as obs_main
    trace, _ = _write_recovery_fixture(tmp_path)
    assert obs_main(["summary", "--trace", trace]) == 0
    out = capsys.readouterr().out
    assert "elastic" in out and "3 spans" in out

    reg = MetricsRegistry()
    reg.counter("a_total").inc()
    pj = str(tmp_path / "m.jsonl")
    reg.write(pj)
    assert obs_main(["metrics", pj]) == 0
    pp = str(tmp_path / "m.prom")
    reg.write(pp)
    assert obs_main(["metrics", pp]) == 0
    assert "a_total 1" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# ci/check_bench_regression.py
# ---------------------------------------------------------------------------

def _fake_report(tmp_path, *, seconds=2.0, ratio=1.0, status="ok"):
    rep = {"suites": {"train_smoke": {"status": status,
                                      "seconds": seconds}},
           "entries": [{"name": "train_smoke_phantom",
                        "ratios": {"energy_j_per_iter": ratio}}]}
    p = str(tmp_path / "rep.json")
    with open(p, "w") as f:
        json.dump(rep, f)
    return p


def _check(args):
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "ci",
                                      "check_bench_regression.py")]
        + args, capture_output=True, text=True)


def test_bench_regression_gate(tmp_path):
    rep = _fake_report(tmp_path)
    base = str(tmp_path / "base.json")
    r = _check(["--report", rep, "--baseline", base,
                "--update-baseline"])
    assert r.returncode == 0, r.stderr

    # fresh baseline passes
    r = _check(["--report", rep, "--baseline", base])
    assert r.returncode == 0, r.stderr + r.stdout
    assert "OK" in r.stdout

    # perturbed ratio fails
    bad = _fake_report(tmp_path, ratio=2.0)
    r = _check(["--report", bad, "--baseline", base])
    assert r.returncode == 1
    assert "ratio train_smoke_phantom/energy_j_per_iter" in r.stderr

    # suite wall-time blowup fails
    slow = _fake_report(tmp_path, seconds=60.0)
    r = _check(["--report", slow, "--baseline", base])
    assert r.returncode == 1
    assert "wall" in r.stderr

    # failed suite status fails regardless of bands
    broke = _fake_report(tmp_path, status="failed")
    r = _check(["--report", broke, "--baseline", base])
    assert r.returncode == 1


def test_bench_regression_checked_in_baseline_matches_schema():
    p = os.path.join(ROOT, "ci", "bench_baseline.json")
    base = json.load(open(p))
    assert base["schema"] == "bench-baseline/v1"
    assert base["suites"] and base["ratios"]
    # satellite: the analytic suites must report real (non-zero) wall
    # seconds now that run.py times them with perf_counter
    for name in ("fig6_large", "roofline"):
        assert base["suites"][name] > 0.0, (name, base["suites"])


# ---------------------------------------------------------------------------
# elastic integration: trace spans vs the priced recovery account
# ---------------------------------------------------------------------------

def _elastic_cfg(tmp_path, **kw):
    from repro.train.elastic import ElasticConfig
    base = dict(workdir=str(tmp_path / "elastic"), devices=8, hosts=4,
                width=32, depth=2, batch=16, target_loss=1e-9,
                max_steps=24, checkpoint_every=5, ks=(4,),
                audit_replan=False, heartbeat_timeout_s=2.5,
                initial_strategy="tensor_col")
    base.update(kw)
    return ElasticConfig(**base)


def test_elastic_trace_matches_recovery_account(tmp_path):
    from repro.train.elastic import run_elastic
    from repro.train.fault import FaultScript

    tr = Tracer()
    with use_tracer(tr):
        res = run_elastic(_elastic_cfg(tmp_path), ledger=Ledger(run="t"),
                          fault_script=FaultScript(
                              kills=((12, "host3"),)),
                          log_fn=lambda *a, **k: None)
    assert not res.aborted and len(res.recoveries) == 1

    doc = tr.to_chrome()
    names = {e["name"] for e in span_events(doc)}
    assert {"elastic/run", "elastic/plan", "elastic/compile",
            "elastic/replan", "elastic/restore",
            "elastic/step"} <= names
    # the detection instant marks the trace
    assert any(e["name"] == "elastic/detect"
               for e in doc["traceEvents"] if e["ph"] == "i")

    # recovery spans must sum to the priced recovery-account seconds
    from repro.launch.obs import RECOVERY_SPANS
    span_s = sum(e["dur"] * 1e-6 for e in span_events(doc)
                 if e["name"] in RECOVERY_SPANS)
    acct = res.account
    assert acct["schema"] == "recovery-account/v1"
    acct_s = sum(float(acct.get(k, 0.0))
                 for k in RECOVERY_SPANS.values())
    assert acct_s > 0
    assert span_s == pytest.approx(acct_s, rel=0.35)

    # the run span links the elastic ledger entry
    run_ev = [e for e in span_events(doc)
              if e["name"] == "elastic/run"][0]
    assert run_ev["args"]["ledger"]["kind"] == "elastic"


def test_elastic_slow_step_trips_watchdog(tmp_path):
    """An injected slow step trips the watchdog mid-run (anomaly row in
    the ledger); the same config without the injection stays silent."""
    from repro.train.elastic import run_elastic

    ledger = Ledger(run="t")
    wd = EnergyDriftWatchdog(ledger=ledger, name="t")
    res = run_elastic(_elastic_cfg(tmp_path, max_steps=16,
                                   slow_steps=(12,)),
                      watchdog=wd, ledger=ledger,
                      log_fn=lambda *a, **k: None)
    assert not res.aborted
    assert any(t.kind == "spike" and t.step == 12 for t in wd.trips)
    assert any(e.kind == "anomaly" for e in ledger.entries)

    wd2 = EnergyDriftWatchdog(name="t2")
    res2 = run_elastic(_elastic_cfg(tmp_path, max_steps=16,
                                    workdir=str(tmp_path / "clean")),
                       watchdog=wd2, log_fn=lambda *a, **k: None)
    assert not res2.aborted
    assert wd2.trips == []
