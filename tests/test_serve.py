"""Serving correctness: decode-with-cache must reproduce prefill logits
(cache consistency), and the continuous-batching engine must schedule,
generate and refill slots."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_config
from repro.parallel.axes import MeshAxes
from repro.parallel.params import materialize
from repro.models.model import model_decls
from repro.serve.engine import Request, ServeEngine, make_serve_fns
from helpers import make_batch


@pytest.mark.parametrize("arch", ["chatglm3-6b", "mamba2-370m",
                                  "jamba-1.5-large-398b", "olmoe-1b-7b"])
def test_decode_consistent_with_prefill(mesh24, arch):
    """logits(decode token t | cache of prefix t) == per-position logits
    of the full forward over the t+1 prefix — validates every family's
    cache path (attention KV, mamba conv/ssm state, MoE routing) end to
    end.  (qwen2.5's ring path is covered by test_attention decode.)"""
    from jax.sharding import PartitionSpec as P
    from repro.models.model import forward_logits
    from helpers import smap

    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        # ample capacity: token drops depend on batch composition, which
        # differs between the S+1-token reference and 1-token decode; this
        # test isolates CACHE consistency (drops are covered in test_moe)
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    axes = MeshAxes.from_mesh(mesh24)
    B, S = 4, 32
    shape = ShapeConfig("t", 2 * S, B, "decode")
    prefill_fn, decode_fn, cache_sds, _ = make_serve_fns(cfg, mesh24, shape)
    decls = model_decls(cfg, axes)
    params = materialize(decls, 3)

    batch = make_batch(cfg, B, S + 1)
    toks_full = np.asarray(batch["tokens"])[:, :S + 1]

    # prefill the first S tokens, pad cache, decode token at position S
    pre_batch = {**_strip(batch), "tokens": jnp.asarray(toks_full[:, :S])}
    pre_batch = _trim_modalities(pre_batch, S)
    lg_a, cache_a = prefill_fn(params, pre_batch)
    cache_a = jax.tree.map(
        lambda c, s: jnp.pad(c, [(0, t - g) for g, t in
                                 zip(c.shape, s.shape)]),
        cache_a, cache_sds)
    nxt = toks_full[:, S:S + 1]
    lg_dec, _ = decode_fn(params, cache_a, jnp.asarray(nxt),
                          jnp.full((B,), S, jnp.int32))

    # reference: full forward over S+1 tokens, logits at position S
    from repro.parallel.params import specs
    from repro.parallel.axes import resolve_spec
    from repro.launch.specs import input_specs
    _, in_spec = input_specs(cfg, ShapeConfig("t", S + 1, B, "prefill"),
                             axes)
    bspecs = jax.tree.map(lambda sp: resolve_spec(sp, axes), in_spec,
                          is_leaf=lambda x: isinstance(x, P))
    pspecs = jax.tree.map(lambda sp: resolve_spec(sp, axes), specs(decls))
    ref_fn = smap(lambda p, bb: forward_logits(cfg, axes, p, bb),
                  mesh24, (pspecs, bspecs), P(("data",), None, None))
    ref_batch = {**_strip(batch), "tokens": jnp.asarray(toks_full)}
    lg_ref = ref_fn(params, ref_batch)[:, S:S + 1]
    # chunked (prefill) vs stepwise (decode) SSD recurrence are different
    # fp summation orders; bf16 over 8 hybrid layers leaves ~0.1 jitter
    atol = 0.1 if cfg.family == "hybrid" else 5e-2
    np.testing.assert_allclose(
        np.asarray(lg_dec)[..., :cfg.vocab_size],
        np.asarray(lg_ref)[..., :cfg.vocab_size], rtol=5e-2, atol=atol)


def _trim_modalities(batch, S):
    out = {}
    for k, v in batch.items():
        if k == "positions":
            out[k] = v[:, :, :S]
        elif k == "frames":
            out[k] = v[:, :S]
        else:
            out[k] = v
    return out


def _strip(batch):
    return {k: v for k, v in batch.items() if k != "labels"}


def test_engine_generates_and_refills(mesh24):
    cfg = get_config("chatglm3-6b", smoke=True)
    axes = MeshAxes.from_mesh(mesh24)
    decls = model_decls(cfg, axes)
    params = materialize(decls, 1)
    eng = ServeEngine(cfg, mesh24, params, slots=4, max_len=64)
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size, 16,
                                       dtype=np.int32).astype(np.int32),
                    max_new_tokens=6) for _ in range(6)]
    done = eng.run(reqs, max_steps=200)
    assert all(r.done for r in done)
    for r in done:
        assert len(r.out_tokens) >= 6
        assert all(0 <= t < cfg.vocab_size + 200 for t in r.out_tokens)


def test_engine_greedy_deterministic(mesh24):
    cfg = get_config("stablelm-3b", smoke=True)
    axes = MeshAxes.from_mesh(mesh24)
    decls = model_decls(cfg, axes)
    params = materialize(decls, 2)
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab_size, 16).astype(np.int32)

    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, mesh24, params, slots=2, max_len=64)
        reqs = [Request(prompt=prompt.copy(), max_new_tokens=5)]
        eng.run(reqs, max_steps=50)
        outs.append(tuple(reqs[0].out_tokens))
    assert outs[0] == outs[1]


def test_engine_close_flushes_tail_window(mesh24):
    """A short session (submit + a few steps, no run()) must not drop
    its metered tail: close() flushes to the ledger, idempotently, and
    the context-manager path closes on exit."""
    from repro.telemetry import Ledger

    cfg = get_config("stablelm-3b", smoke=True)
    axes = MeshAxes.from_mesh(mesh24)
    decls = model_decls(cfg, axes)
    params = materialize(decls, 2)
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab_size, 16).astype(np.int32)

    led = Ledger(run="close-test")
    with ServeEngine(cfg, mesh24, params, slots=2, max_len=64,
                     ledger=led) as eng:
        eng.submit([Request(prompt=prompt.copy(), max_new_tokens=4)])
        eng.step()
        assert len(led) == 0          # nothing flushed mid-session
    kinds = {e.kind for e in led.entries}
    assert {"prefill", "decode"} <= kinds
    n = len(led)
    eng.close()                       # idempotent: no duplicate records
    assert len(led) == n

    # run() still flushes its own window; a following close adds nothing
    led2 = Ledger(run="close-test-2")
    eng2 = ServeEngine(cfg, mesh24, params, slots=2, max_len=64,
                       ledger=led2)
    eng2.run([Request(prompt=prompt.copy(), max_new_tokens=4)],
             max_steps=50)
    n2 = len(led2)
    assert n2 > 0
    eng2.close()
    assert len(led2) == n2
