"""Telemetry / energy-ledger tests.

The acceptance pins: on the 8-way host-platform mesh, the MEASURED
compiled-HLO account of the tensor_col and phantom FFN probe steps must
match the strategy-PREDICTED account within tolerance (wire bytes ~exact
under the shared ring model; flops within the documented 3x-GEMM-model
slack), and ``training=False`` must drop the backward comm events (the
inference path of ``costs_from_strategies``).
"""
import json

import numpy as np
import pytest

from repro.configs.base import ModelConfig, PhantomConfig, ProjectionSpec
from repro.core.energy import (TPU_PEAK_FLOPS, comm_time_us,
                               costs_from_strategies)
from repro.parallel.strategies import make_strategy
from repro.telemetry import (Ledger, LedgerEntry, StepMeter,
                             event_wire_bytes, events_for, load_report,
                             measure_ffn_step, strategy_prediction)


def _ffn_cfg(impl, n=512, L=2, k=8):
    return ModelConfig(name=f"probe-{impl}", family="ffn", num_layers=L,
                       d_model=n, ffn_width=n, ffn_depth=L, ffn_impl=impl,
                       mlp="relu", phantom=PhantomConfig(k=k))


# ---------------------------------------------------------------------------
# measured (compiled HLO) vs predicted (strategy sums) — the core pin
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl,flops_rtol", [("dense", 0.05),
                                             ("phantom", 0.25)])
def test_measured_matches_predicted_ffn_step(mesh18, impl, flops_rtol):
    """Wire bytes within 2% (same ring model both sides; the slack is
    scalar loss psums), flops within the 3x-GEMM model's documented
    slack (tight for TP; phantom's backward rank-k factor ops are known
    to be undercounted by ~10-15%)."""
    cfg = _ffn_cfg(impl)
    measured, predicted = measure_ffn_step(cfg, mesh18, 32)
    assert measured["collective_wire_bytes_per_device"] == pytest.approx(
        predicted["collective_wire_bytes_per_device"], rel=0.02)
    assert measured["collective_m_floats"] == pytest.approx(
        predicted["collective_m_floats"], rel=0.02)
    assert measured["flops_per_device"] == pytest.approx(
        predicted["flops_per_device"], rel=flops_rtol)
    # the model is an operator-count lower bound of the real program
    assert measured["flops_per_device"] \
        >= predicted["flops_per_device"] * 0.99
    # the lowered HLO emits the Table II schedule: AG fwd + RS bwd
    assert measured["collectives"]["all-gather"]["count"] >= 1
    assert measured["collectives"]["reduce-scatter"]["count"] >= 1


def test_measured_step_executes_and_meters(mesh18):
    """steps>0 also runs the compiled probe and records wall stats."""
    measured, _ = measure_ffn_step(_ffn_cfg("phantom", n=128, k=4),
                                   mesh18, 16, steps=2)
    assert measured["calls"] == 3          # warmup + 2
    assert measured["wall_us_median"] > 0


# ---------------------------------------------------------------------------
# the inference path: training=False drops bwd events and the 3x factor
# ---------------------------------------------------------------------------

def test_training_false_drops_bwd_comm_events():
    n, p, L, batch, k = 4096, 8, 2, 64, 8
    for spec in (ProjectionSpec(kind="tensor_col"),
                 ProjectionSpec(kind="phantom", k=k)):
        st = make_strategy(spec, n, n, p, bias=True)
        a_tr, b_tr = costs_from_strategies([st], p, L, batch,
                                           TPU_PEAK_FLOPS, training=True)
        a_inf, b_inf = costs_from_strategies([st], p, L, batch,
                                             TPU_PEAK_FLOPS,
                                             training=False)
        # alpha: the 3x fwd+bwd pass factor collapses to 1x
        assert a_inf == pytest.approx(a_tr / 3.0, rel=1e-12)
        # beta: only the forward all-gather remains
        (ag, rs) = st.comm_events(batch)
        assert (ag.phase, rs.phase) == ("fwd", "bwd")
        expect = comm_time_us(ag.collective, ag.m_floats, p) * L * 1e-6
        assert b_inf == pytest.approx(expect, rel=1e-12)
        assert b_inf < b_tr


def test_events_for_filters_phase():
    st = make_strategy(ProjectionSpec(kind="tensor_col"), 256, 256, 8)
    both = events_for([st], 32, training=True)
    fwd = events_for([st], 32, training=False)
    assert {e.phase for e in both} == {"fwd", "bwd"}
    assert [e.phase for e in fwd] == ["fwd"]


def test_strategy_prediction_inference_fields():
    st = make_strategy(ProjectionSpec(kind="phantom", k=4), 256, 256, 8)
    tr = strategy_prediction([st], 8, 2, 32, training=True)
    inf = strategy_prediction([st], 8, 2, 32, training=False)
    assert inf["flops_per_device"] == pytest.approx(
        tr["flops_per_device"] / 3.0)
    assert inf["collective_wire_bytes_per_device"] == pytest.approx(
        tr["collective_wire_bytes_per_device"] / 2.0)
    assert inf["energy_j_per_iter"] < tr["energy_j_per_iter"]


# ---------------------------------------------------------------------------
# wire-byte model parity with the HLO parser's ring formulas
# ---------------------------------------------------------------------------

def test_event_wire_bytes_ring_model():
    from repro.parallel.strategies.base import CommEvent
    p, m = 8, 1000.0
    # AG: result = m*p floats; parser wire = result_bytes*(p-1)/p
    assert event_wire_bytes(CommEvent("all_gather", m), p) \
        == pytest.approx(m * p * 4 * (p - 1) / p)
    # RS: result = m floats; parser wire = result_bytes*(p-1)
    assert event_wire_bytes(CommEvent("reduce_scatter", m), p) \
        == pytest.approx(m * 4 * (p - 1))
    assert event_wire_bytes(CommEvent("all_reduce", m), p) \
        == pytest.approx(2 * m * 4 * (p - 1) / p)
    assert event_wire_bytes(CommEvent("all_gather", m), 1) == 0.0


# ---------------------------------------------------------------------------
# the shared analysis cache (dry-run + planner entry point)
# ---------------------------------------------------------------------------

def test_analyze_lowered_caches_compiles_and_analyses():
    """Re-lowering an identical module must not recompile or reparse:
    the cache keys on the lowered/optimized HLO text."""
    import jax
    import jax.numpy as jnp

    from repro.telemetry import analyze_lowered

    f = jax.jit(lambda x: jnp.sum(x * 2.0))
    x = jax.ShapeDtypeStruct((16,), jnp.float32)
    c1, comp1 = analyze_lowered(f.lower(x), keep_compiled=True)
    c2, comp2 = analyze_lowered(f.lower(x), keep_compiled=True)
    assert comp1 is comp2                  # compile served from cache
    assert c1 is c2                        # analysis memoized too
    assert c1.flops >= 0


# ---------------------------------------------------------------------------
# StepMeter
# ---------------------------------------------------------------------------

def test_step_meter_records_and_excludes_warmup():
    meter = StepMeter("unit", warmup=1)
    calls = []

    def fn(x):
        calls.append(x)
        return np.float32(x)

    for i in range(4):
        out = meter.call(fn, i)
    assert calls == [0, 1, 2, 3] and float(out) == 3.0
    assert meter.calls == 4
    assert len(meter.steady) == 3          # warmup excluded
    s = meter.summary()
    assert s["calls"] == 4 and s["wall_us_mean"] > 0
    assert s["total_s"] > 0
    wrapped = meter.wrap(fn)
    wrapped(9)
    assert meter.calls == 5


# ---------------------------------------------------------------------------
# Ledger
# ---------------------------------------------------------------------------

def test_ledger_ratios_jsonl_and_report(tmp_path):
    jsonl = tmp_path / "ledger.jsonl"
    led = Ledger(run="test", jsonl_path=str(jsonl), meta={"who": "pytest"})
    led.entry("joined_row", suite="s", kind="train", impl="phantom", p=8,
              measured={"flops_per_device": 110.0,
                        "collective_wire_bytes_per_device": 100.0},
              predicted={"flops_per_device": 100.0,
                         "collective_wire_bytes_per_device": 100.0})
    led.entry("measured_only", suite="s",
              measured={"wall_us_median": 5.0})
    led.suite_ok("s", 1.0)
    led.suite_failed("t", "ValueError: boom")

    e = led.entries[0]
    assert e.ratios()["flops_per_device"] == pytest.approx(1.1)
    assert e.ratios()["collective_wire_bytes_per_device"] \
        == pytest.approx(1.0)
    assert led.entries[1].ratios() == {}
    assert [x.name for x in led.joined()] == ["joined_row"]

    lines = [json.loads(l) for l in jsonl.read_text().splitlines()]
    assert len(lines) == 2
    assert lines[0]["ratios"]["flops_per_device"] == pytest.approx(1.1)

    path = tmp_path / "BENCH_report.json"
    led.write_report(str(path))
    rep = load_report(str(path))
    assert rep["counts"] == {"entries": 2, "joined": 1}
    assert rep["suites"]["t"]["status"] == "failed"
    assert rep["meta"] == {"who": "pytest"}


def test_load_report_rejects_unknown_schema(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"schema": "nope/v9"}))
    with pytest.raises(ValueError):
        load_report(str(p))


def test_ledger_entry_serialization_drops_empty():
    d = LedgerEntry(name="x", measured={"a": 1.0}).as_dict()
    assert "predicted" not in d and d["measured"] == {"a": 1.0}
    assert d["name"] == "x"
