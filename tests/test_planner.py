"""Energy-aware configuration planner tests.

The acceptance pins: calibration round-trips synthetic ledgers (known
α/β scales recovered within tolerance, documented paper-defaults
fallback otherwise), constraint filtering rejects plans that don't fit
HBM, the Pareto frontier is non-dominated and monotone, and the CLI
writes a schema-valid ``PLAN_report.json`` on the 8-way CPU mesh whose
matched-loss comparison shows a phantom plan on a smaller mesh beating
every full-mesh tensor plan's calibrated energy.
"""
import json
import os

import pytest

from repro.core.energy import PAPER_COLLECTIVE_FITS
from repro.planner import (Constraints, PlanCandidate, calibrate_from_ledger,
                           calibrate_from_rows, enumerate_plans,
                           filter_feasible, fit_loss_curve,
                           hbm_bytes_estimate, least_squares_scale,
                           load_plan_report, paper_default_calibration,
                           pareto_frontier, score_plan, score_plans)


def _synthetic_rows(s_alpha, s_beta, impl="phantom", noise=0.0):
    rows = []
    for i, pred in enumerate((1e6, 2e6, 4e6, 8e6)):
        jitter = 1.0 + noise * ((-1) ** i)
        rows.append({
            "name": f"synth{i}", "suite": "synth", "kind": "train",
            "impl": impl,
            "measured": {
                "flops_per_device": s_alpha * pred * jitter,
                "collective_wire_bytes_per_device":
                    s_beta * (pred / 8) * jitter,
            },
            "predicted": {
                "flops_per_device": pred,
                "collective_wire_bytes_per_device": pred / 8,
            }})
    return rows


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_calibration_roundtrips_synthetic_ledger(tmp_path):
    """Known α/β scales written into a synthetic JSONL ledger must be
    recovered by the least-squares fit within tolerance."""
    s_alpha, s_beta = 1.23, 0.91
    rows = (_synthetic_rows(s_alpha, s_beta, "phantom", noise=0.02)
            + _synthetic_rows(1.01, 1.0, "tensor_col", noise=0.02))
    path = tmp_path / "ledger.jsonl"
    path.write_text("".join(json.dumps(r) + "\n" for r in rows))

    calib = calibrate_from_ledger(jsonl_path=str(path))
    assert calib.source == "ledger-fit"
    assert calib.alpha_scale["phantom"] == pytest.approx(s_alpha, rel=0.03)
    assert calib.beta_scale["phantom"] == pytest.approx(s_beta, rel=0.03)
    assert calib.alpha_scale["tensor_col"] == pytest.approx(1.01, rel=0.03)
    # provenance names the rows each constant was fitted from
    prov = calib.provenance["alpha_scale.phantom"]
    assert prov["source"] == "ledger-fit" and prov["n_rows"] == 4
    assert "synth0" in prov["rows"]
    # lowrank inherits phantom's fit; unknown kinds fall back to 1.0
    assert calib.scales_for("lowrank_distill")[0] \
        == pytest.approx(s_alpha, rel=0.03)
    assert calib.scales_for("tensor_row") == (1.0, 1.0, 1.0)


def test_calibration_fits_nu_and_collective_constants():
    rows = [
        {"name": "table1_tp_iters", "impl": "tensor_col", "kind": "train",
         "measured": {"iterations": 100},
         "extra": {"target_loss": 0.175}},
        {"name": "table1_pp_k8_iters", "impl": "phantom", "kind": "train",
         "measured": {"iterations": 80},
         "extra": {"target_loss": 0.175}},
        {"name": "comm_fit_all_gather", "impl": "all_gather",
         "kind": "collective",
         "measured": {"c1_us": 200.0, "c2_us_per_float": 0.007}},
    ]
    calib = calibrate_from_rows(rows)
    assert calib.nu_scale["phantom"] == pytest.approx(0.8)
    assert calib.collective_fits["all_gather"] == (200.0, 0.007)
    # un-fitted collectives keep the paper's Table III constants
    assert calib.collective_fits["broadcast"] \
        == PAPER_COLLECTIVE_FITS["broadcast"]


def test_calibration_fallback_is_paper_defaults(tmp_path):
    calib = calibrate_from_ledger(jsonl_path=str(tmp_path / "none.jsonl"))
    assert "paper defaults" in calib.source
    assert calib.collective_fits == PAPER_COLLECTIVE_FITS
    assert calib.scales_for("phantom") == (1.0, 1.0, 1.0)
    assert any("paper defaults" in str(v.get("source", ""))
               for v in calib.provenance.values())


def test_least_squares_scale_exact():
    assert least_squares_scale([(2.0, 4.0), (3.0, 6.0)]) \
        == pytest.approx(2.0)
    assert least_squares_scale([]) == 1.0


# ---------------------------------------------------------------------------
# search space + constraints
# ---------------------------------------------------------------------------

def test_enumerate_plans_shapes_and_regime():
    plans = enumerate_plans(8, width=512, depth=2, batch=64)
    names = {p.name for p in plans}
    # tensor baselines use the full budget; phantom may downsize
    assert all(p.devices == 8 for p in plans
               if p.strategy == "tensor_col")
    assert any(p.devices < 8 for p in plans if p.strategy == "phantom")
    # phantom needs >= 2 ranks and k inside the Eqn. 8 regime
    assert all(p.tp >= 2 for p in plans if p.strategy == "phantom")
    assert all(p.k < p.width // p.tp for p in plans
               if p.strategy == "phantom")
    assert "phantom_n512_mesh1x2_k4" in names
    # the config side round-trips through the ProjectionStrategy API
    cfg = next(iter(plans)).model_config()
    assert cfg.projection_spec("ffn_layer").kind in ("tensor_col",
                                                     "phantom")


def test_constraint_filtering_rejects_hbm_misfits():
    plans = enumerate_plans(8, width=512, depth=2, batch=64)
    tiny = Constraints(max_devices=8, hbm_bytes_per_device=1e4)
    kept, rejected = filter_feasible(plans, tiny)
    assert kept == [] and len(rejected) == len(plans)
    assert all("HBM" in r.reason for r in rejected)

    roomy = Constraints(max_devices=8)
    kept, rejected = filter_feasible(plans, roomy)
    assert len(kept) == len(plans) and rejected == []

    # the estimate orders sensibly: more tp ways -> smaller footprint
    est2 = hbm_bytes_estimate(PlanCandidate(1, 2, "tensor_col", 512, 2, 64))
    est8 = hbm_bytes_estimate(PlanCandidate(1, 8, "tensor_col", 512, 2, 64))
    assert est8 < est2


# ---------------------------------------------------------------------------
# scoring + frontier
# ---------------------------------------------------------------------------

def _scored(width=1024):
    calib = paper_default_calibration()
    plans = enumerate_plans(8, width=width, depth=2, batch=64)
    kept, _ = filter_feasible(plans, Constraints(max_devices=8))
    return score_plans(kept, calib, iterations=100.0)


def test_scoring_prices_dp_gradient_sync():
    """A pure-DP plan must not look communication-free."""
    calib = paper_default_calibration()
    dp_only = score_plan(PlanCandidate(8, 1, "tensor_col", 1024, 2, 64),
                         calib, iterations=1.0)
    assert dp_only.beta_s > 0
    one_dev = score_plan(PlanCandidate(1, 1, "tensor_col", 1024, 2, 64),
                         calib, iterations=1.0)
    assert one_dev.beta_s == 0.0


def test_frontier_monotone_and_nondominated():
    scored = _scored()
    # classic 2-key curve: sorted by energy, step time non-increasing
    front2 = pareto_frontier(scored, keys=("energy_j_total",
                                           "step_time_s"))
    assert front2
    energies = [s.energy_j_total for s in front2]
    times = [s.step_time_s for s in front2]
    assert energies == sorted(energies)
    assert all(times[i] >= times[i + 1] for i in range(len(times) - 1))
    # default 3-objective frontier (energy, step time, per-device HBM):
    # contains the 2-key curve and no point is dominated by ANY plan
    front = pareto_frontier(scored)
    assert {id(s) for s in front2} <= {id(s) for s in front}
    for f in front:
        for s in scored:
            if s is f:
                continue
            fv = (f.energy_j_total, f.step_time_s, f.hbm_bytes_per_device)
            sv = (s.energy_j_total, s.step_time_s, s.hbm_bytes_per_device)
            assert not (all(a <= b for a, b in zip(sv, fv)) and sv != fv)


def test_frontier_contains_pipeline_plans():
    """pp>1 plans are the memory-lean frontier points: with the pipe
    axis in the enumeration, some pipelined plan must be non-dominated
    on (energy, step time, per-device HBM)."""
    calib = paper_default_calibration()
    plans = enumerate_plans(8, width=512, depth=2, batch=64, pps=(1, 2))
    assert any(p.pp > 1 for p in plans)
    # pp slices devices out of dp, never inflates the budget
    assert all(p.devices <= 8 for p in plans)
    front = pareto_frontier(score_plans(plans, calib, iterations=100.0))
    pp_front = [s for s in front if s.plan.pp > 1]
    assert pp_front, [s.plan.name for s in front]
    # the pipelined plan offers lower per-device HBM than its pp=1
    # sibling on the same (dp*pp, tp) device count
    for s in pp_front:
        sib = [o for o in front if o.plan.pp == 1
               and o.plan.tp == s.plan.tp
               and o.plan.strategy == s.plan.strategy]
        for o in sib:
            assert s.hbm_bytes_per_device < o.hbm_bytes_per_device


def test_loss_curve_fit_and_inversion():
    # exact power law round-trips
    curve = fit_loss_curve("phantom", [4, 8, 16],
                           [0.4 * (k / 4.0) ** -0.5 for k in (4, 8, 16)],
                           width=512, pilot_tp=4)
    assert curve.b == pytest.approx(-0.5, rel=1e-6)
    assert curve.loss_at(8) == pytest.approx(0.4 / 2 ** 0.5, rel=1e-6)
    assert curve.k_for(0.2) is not None
    # non-decreasing curves refuse to invert
    flat = fit_loss_curve("phantom", [4, 8], [0.3, 0.3], 512, 4)
    assert flat.k_for(0.2) is None


# ---------------------------------------------------------------------------
# the CLI on the 8-way CPU mesh (pilots included)
# ---------------------------------------------------------------------------

def test_plan_cli_writes_schema_valid_report(tmp_path):
    import repro.launch.plan as plan_cli

    # width 512 is the smallest CPU width where the paper's regime
    # reproduces (table1_energy.py documents the flip below it)
    out = tmp_path / "PLAN_report.json"
    rc = plan_cli.main([
        "--devices", "8", "--target-loss", "0.25", "--width", "512",
        "--batch", "64", "--ks", "4,8", "--pilot-steps", "80",
        "--pilot-tp", "4", "--ledger", str(tmp_path / "absent.jsonl"),
        "--out", str(out)])
    assert rc == 0

    report = load_plan_report(str(out))      # validates the schema tag
    assert report["schema"] == "plan-report/v1"
    assert report["frontier"], "frontier must be non-empty"
    # calibration provenance is recorded (paper-defaults fallback here)
    assert "paper defaults" in report["calibration"]["source"]
    assert report["calibration"]["provenance"]
    # pilots ran and the iso-loss section is populated
    assert report["iso_loss"]["pilots"]
    assert report["iso_loss"]["target_loss"] == 0.25

    # the acceptance inequality: some phantom plan on a smaller mesh
    # beats EVERY full-mesh tensor plan at matched predicted loss
    matched = [s for s in report["plans"]
               if s.get("notes", {}).get("reached_target")]
    tensor_full = [s for s in matched
                   if s["plan"]["strategy"] == "tensor_col"
                   and s["plan"]["devices"] == 8]
    phantom_small = [s for s in matched
                     if s["plan"]["strategy"] == "phantom"
                     and s["plan"]["devices"] < 8]
    assert tensor_full and phantom_small
    best_ph = min(s["energy_j_total"] for s in phantom_small)
    assert all(best_ph < s["energy_j_total"] for s in tensor_full)
    assert report["comparison"]["phantom_dominates"] is True
    # the winner is applied-ready: it carries a projection spec
    assert report["winner"]["plan"]["projection_spec"]["kind"]
