"""End-to-end audit: real lowered entrypoints must reconcile.

The seeded fixtures in test_audit_rules.py prove each rule FIRES; these
prove the shipped accounts PASS them — the audit's two-sided acceptance.
Also covers the planner gate (``audit_plans``) and the ``--source-only``
CLI path.
"""
import json

from repro.analysis import run_audit
from repro.configs.base import get_config, phantom_projection_map


def _small_ffn(width=512, phantom=True):
    cfg = get_config("paper-ffn-4k", smoke=True).replace(
        d_model=width, ffn_width=width)
    if phantom:
        cfg = cfg.replace(projections=phantom_projection_map(
            4, ffn_layer=True, ffn=True))
    return cfg


def test_ffn_train_unit_reconciles(mesh18):
    from repro.analysis import ffn_train_unit
    unit = ffn_train_unit(_small_ffn(), mesh18, 64)
    res = run_audit([unit])
    assert res.ok, "\n".join(res.summary_lines())
    # the probe really lowered collectives and the account priced them
    assert unit.measured_buckets(), "probe must issue collectives"
    assert unit.predicted_buckets()


def test_ffn_train_unit_dp_mesh_reconciles(mesh24):
    """dp>1: layer collectives run at per-shard rows and the grad psum
    joins the account — the exact bucket the audit once caught
    mispriced."""
    from repro.analysis import ffn_train_unit
    unit = ffn_train_unit(_small_ffn(), mesh24, 64)
    res = run_audit([unit])
    assert res.ok, "\n".join(res.summary_lines())
    assert ("all_reduce", 2) in unit.predicted_buckets()  # dp grad sync


def test_pipeline_unit_reconciles(mesh222):
    from repro.analysis import pipeline_unit
    cfg = _small_ffn().replace(microbatches=4)
    cfg = cfg.replace(pipeline=cfg.pipeline.__class__(stages=2))
    unit = pipeline_unit(cfg, mesh222, 64)
    res = run_audit([unit])
    assert res.ok, "\n".join(res.summary_lines())
    # the 1F1B p2p hops are priced AND lowered on the pp axis
    assert ("collective_permute", 2) in unit.predicted_buckets()
    assert ("collective_permute", 2) in unit.measured_buckets()


def test_audit_plans_gates_candidates():
    from repro.analysis import audit_plans
    from repro.planner.space import PlanCandidate
    good = PlanCandidate(dp=1, tp=2, strategy="phantom", width=256,
                         depth=2, batch=64, k=4)
    res = audit_plans([good])
    assert res[good.name]["ok"], res[good.name]["errors"]

    # an unlowerable candidate is an audit error, not a crash
    bad = PlanCandidate(dp=1, tp=3, strategy="phantom", width=256,
                        depth=2, batch=64, k=4)   # 256 % 3 != 0
    res = audit_plans([bad])
    assert not res[bad.name]["ok"]
    assert "could not lower" in res[bad.name]["errors"][0]


def test_audit_cli_source_only(tmp_path):
    from repro.launch import audit as audit_cli
    out = tmp_path / "AUDIT_report.json"
    rc = audit_cli.main(["--source-only", "--out", str(out),
                         "--baseline", str(tmp_path / "absent.json")])
    assert rc == 0, "repo source must be lint-clean"
    rec = json.load(open(out))
    assert rec["schema"] == "audit-report/v1"
    assert rec["ok"] is True
    assert rec["counts"]["error"] == 0


def test_audit_cli_update_baseline_ratchet(tmp_path, monkeypatch):
    """--update-baseline accepts today's findings; the re-run suppresses
    exactly those and nothing new."""
    from repro.analysis import Finding, load_baseline, run_audit
    from repro.analysis.findings import write_baseline
    f = Finding("collective-accounting", "error", "u", "m", key="k")
    path = tmp_path / "AUDIT_baseline.json"
    write_baseline([f], str(path))
    base = load_baseline(str(path))
    res = run_audit([], baseline=base)
    assert res.ok
    assert res.stale_suppressions == [f.fingerprint]
