"""Golden-value regression pins for the energy model.

Each strategy's ``flops()``/``comm_events()`` account (Table II / Eqn.
26 closed forms, including the pipeline stage-boundary p2p events), the
1F1B schedule geometry, and the executed-SPMD pipeline prediction are
compared against the seeded fixture ``tests/fixtures/golden_costs.json``
— an energy-model refactor that changes ANY prediction fails here until
the fixture is regenerated deliberately (see tests/make_golden_costs.py).
"""
import json
import math

import pytest

from make_golden_costs import FIXTURE, compute


@pytest.fixture(scope="module")
def golden():
    with open(FIXTURE) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def live():
    return compute()


def _assert_same(path, want, got):
    if isinstance(want, dict):
        assert isinstance(got, dict) and set(want) == set(got), path
        for key in want:
            _assert_same(f"{path}.{key}", want[key], got[key])
    elif isinstance(want, (list, tuple)):
        got_l = list(got)
        assert len(want) == len(got_l), path
        for i, (w, g) in enumerate(zip(want, got_l)):
            _assert_same(f"{path}[{i}]", w, g)
    elif isinstance(want, float) and not isinstance(want, bool):
        assert got == pytest.approx(want, rel=1e-9, abs=1e-12), \
            f"{path}: fixture {want} != live {got}"
    else:
        # JSON round-trips tuples as lists; normalize before comparing
        assert want == got, f"{path}: fixture {want!r} != live {got!r}"


@pytest.mark.parametrize("section", ["strategies", "closed_forms",
                                     "comm_time_us", "schedule",
                                     "pipeline_prediction"])
def test_golden_section_pinned(golden, live, section):
    # the live table() returns tuples; JSON stores lists — canonicalize
    want, got = golden[section], json.loads(json.dumps(live[section]))
    _assert_same(section, want, got)


def test_fixture_is_sane(golden):
    """Guard against a truncated/hand-edited fixture: the pinned values
    must satisfy the model's own arithmetic identities."""
    st = golden["strategies"]["tensor_col_k0"]
    n, tp, b = st["n"], st["tp"], st["batch"]
    assert st["flops"] == pytest.approx(2.0 * n * (n / tp) * b)
    assert [e[0] for e in st["comm_events"]] == ["all_gather",
                                                 "reduce_scatter"]
    ph = golden["strategies"]["phantom_k8"]
    assert all(e[1] == 8 * b for e in ph["comm_events"])
    sched = golden["schedule"]
    assert sched["num_ticks"] == sched["microbatches"] + sched["stages"] - 1
    assert sched["bubble_fraction"] == pytest.approx(
        (sched["stages"] - 1) / sched["num_ticks"])
    assert sched["p2p_events_ideal"] == 2 * sched["microbatches"]
    assert sched["p2p_events_executed"] == 2 * (sched["num_ticks"] - 1)
    assert not math.isnan(
        golden["pipeline_prediction"]["phantom"]["energy_j_per_iter"])
