"""Seeded-violation fixtures: every audit rule must demonstrably fire.

Each test plants one violation — an intentionally unpriced psum, a
reshard over a non-mesh group, a bf16->f32 upcast, an unhashable static
arg — and asserts the matching rule reports it at the right severity.
The collective fixtures feed synthetic HLO through the REAL parser
(``collective_bytes`` -> ``CompiledCosts``), so the rule is exercised
end-to-end, not against hand-built buckets; the headline fixture lowers
a real shard_map psum and proves the accounting rule catches it
unpriced.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import (ERROR, INFO, WARNING, AuditUnit, Baseline,
                            Finding, PricedCollective, apply_baseline,
                            load_baseline, run_audit, run_rules)
from repro.analysis.rules import (rule_collective_accounting,
                                  rule_dtype_drift,
                                  rule_recompilation_hazard,
                                  rule_sharding_hygiene)
from repro.launch.hlo_analysis import collective_bytes
from repro.telemetry.compiled import CompiledCosts
from helpers import smap


def _unit_from_hlo(hlo_text, predicted, *, default_group=8, axes=None,
                   **kw):
    """Build an AuditUnit whose measured side comes from the REAL HLO
    collective parser."""
    _, breakdown = collective_bytes(hlo_text, default_group=default_group)
    costs = CompiledCosts(collectives=breakdown)
    return AuditUnit(name="fixture", kind="fixture", hlo_text=hlo_text,
                     costs=costs, predicted=predicted,
                     axes=axes or {"dp": 1, "tp": 8}, **kw)


def _findings(fs, rule=None, severity=None):
    return [f for f in fs
            if (rule is None or f.rule == rule)
            and (severity is None or f.severity == severity)]


# ---------------------------------------------------------------------------
# R1 collective-accounting
# ---------------------------------------------------------------------------

def test_unpriced_psum_is_caught(mesh18):
    """The headline fixture: lower a REAL shard_map step containing a
    psum nothing prices, and the accounting rule must flag it as an
    error."""
    def step(x):
        return jax.lax.psum(x * 2.0, "model")       # 8192-float AR

    fn = smap(step, mesh18, P(None, None), P(None, None))
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    hlo = fn.lower(x).compile().as_text()
    unit = _unit_from_hlo(hlo, predicted=[])        # nothing priced
    errs = _findings(rule_collective_accounting(unit),
                     severity=ERROR)
    assert errs, "an unpriced 8192-float psum must be an error"
    assert "unpriced" in errs[0].message
    assert "all_reduce" in errs[0].message


def test_priced_psum_is_clean(mesh18):
    """Control for the fixture above: price the same psum correctly and
    the rule goes quiet."""
    def step(x):
        return jax.lax.psum(x * 2.0, "model")

    fn = smap(step, mesh18, P(None, None), P(None, None))
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    hlo = fn.lower(x).compile().as_text()
    unit = _unit_from_hlo(
        hlo, predicted=[PricedCollective("all_reduce", 64 * 128, 8)])
    assert not _findings(rule_collective_accounting(unit),
                         severity=ERROR)


_AR_BIG = ("  %ar = f32[64,512]{1,0} all-reduce(f32[64,512] %x), "
           "replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add\n")


def test_phantom_prediction_is_caught():
    """Pricing a collective the lowered HLO never issues is the dual
    error (the account bills energy that never flows)."""
    unit = _unit_from_hlo(
        _AR_BIG, predicted=[
            PricedCollective("all_reduce", 64 * 512, 8),
            PricedCollective("reduce_scatter", 32_768, 8)])
    errs = _findings(rule_collective_accounting(unit), severity=ERROR)
    assert len(errs) == 1
    assert "phantom prediction" in errs[0].message
    assert "reduce_scatter" in errs[0].message


def test_mispriced_bytes_and_count_only_mismatch():
    # bytes off by 2x -> error; counts off with bytes agreeing -> info
    unit = _unit_from_hlo(
        _AR_BIG, predicted=[PricedCollective("all_reduce",
                                             2 * 64 * 512, 8)])
    errs = _findings(rule_collective_accounting(unit), severity=ERROR)
    assert len(errs) == 1 and "mispriced" in errs[0].message

    unit2 = _unit_from_hlo(
        _AR_BIG, predicted=[PricedCollective("all_reduce",
                                             64 * 512 / 4, 8, count=4)])
    fs = rule_collective_accounting(unit2)
    assert not _findings(fs, severity=ERROR)
    infos = _findings(fs, severity=INFO)
    assert len(infos) == 1 and "fusion/splitting" in infos[0].message


def test_small_messages_and_loose_units_demote():
    hlo_small = ("  %ar = f32[16]{0} all-reduce(f32[16] %x), "
                 "replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add\n")
    unit = _unit_from_hlo(hlo_small, predicted=[])
    fs = rule_collective_accounting(unit)
    assert _findings(fs, severity=INFO) and not _findings(fs,
                                                          severity=ERROR)
    # the same big unpriced AR on a loose (serving) unit demotes to
    # warning instead of error
    loose = _unit_from_hlo(_AR_BIG, predicted=[], strict=False)
    fs = rule_collective_accounting(loose)
    assert _findings(fs, severity=WARNING) and not _findings(
        fs, severity=ERROR)


def test_wrong_mesh_axis_same_kind_is_two_findings():
    """Matching is by (kind, group): pricing the right kind on the
    wrong mesh axis must NOT reconcile."""
    unit = _unit_from_hlo(
        _AR_BIG, predicted=[PricedCollective("all_reduce", 64 * 512, 4)],
        axes={"dp": 2, "tp": 4})
    errs = _findings(rule_collective_accounting(unit), severity=ERROR)
    kinds = sorted(e.message.split(":")[0] for e in errs)
    assert kinds == ["phantom prediction", "unpriced collective"]


def test_degenerate_group_of_one_collectives_ignored():
    """XLA lowers axis-size-1 psums as {{0},{1},..} collectives that
    move nothing; they must not show up as unpriced traffic."""
    hlo = ("  %ag = f32[64,512]{1,0} all-gather(f32[64,512] %x), "
           "replica_groups={{0},{1},{2},{3},{4},{5},{6},{7}}, "
           "dimensions={0}\n")
    unit = _unit_from_hlo(hlo, predicted=[])
    assert unit.measured_buckets() == {}
    assert rule_collective_accounting(unit) == []


# ---------------------------------------------------------------------------
# R2 sharding-hygiene
# ---------------------------------------------------------------------------

def test_reshard_over_non_mesh_group_warns():
    hlo = ("  %ar = f32[64,512]{1,0} all-reduce(f32[64,512] %x), "
           "replica_groups={{0,1,2},{3,4,5}}, to_apply=%add\n")
    unit = _unit_from_hlo(hlo, predicted=[], axes={"dp": 2, "tp": 4})
    ws = _findings(rule_sharding_hygiene(unit), severity=WARNING)
    assert len(ws) == 1
    assert "group of 3" in ws[0].message
    # mesh-legal groups (1, 2, 4, 8) raise nothing
    ok = _unit_from_hlo(_AR_BIG, predicted=[], axes={"dp": 1, "tp": 8})
    assert rule_sharding_hygiene(ok) == []


def test_memory_blowup_vs_napkin_warns():
    costs = CompiledCosts(memory={"argument_bytes": 9e6,
                                  "temp_bytes": 0.0,
                                  "output_bytes": 0.0})
    unit = AuditUnit(name="fixture", kind="fixture", costs=costs,
                     axes={"tp": 8}, napkin_bytes=1e6)
    ws = _findings(rule_sharding_hygiene(unit), severity=WARNING)
    assert len(ws) == 1 and "blowup" in ws[0].message
    unit.napkin_bytes = 5e6                 # within 8x: fine
    assert rule_sharding_hygiene(unit) == []


# ---------------------------------------------------------------------------
# R3 dtype-drift
# ---------------------------------------------------------------------------

def test_bf16_upcast_flagged_scalars_exempt():
    def f(x, s):
        big = x.astype(jnp.float32) * 2.0           # 512*512 upcast
        small = s.astype(jnp.float32)               # scalar: exempt
        return big.sum() + small

    jaxpr = jax.make_jaxpr(f)(
        jax.ShapeDtypeStruct((512, 512), jnp.bfloat16),
        jax.ShapeDtypeStruct((), jnp.bfloat16))
    unit = AuditUnit(name="fixture", kind="fixture", jaxpr=jaxpr,
                     compute_dtype="bfloat16")
    ws = _findings(rule_dtype_drift(unit), severity=WARNING)
    assert len(ws) == 1
    assert "(512, 512)" in ws[0].message
    # f32 units don't run the rule at all
    unit_f32 = AuditUnit(name="fixture", kind="fixture", jaxpr=jaxpr,
                         compute_dtype="float32")
    assert rule_dtype_drift(unit_f32) == []


def test_dtype_drift_descends_into_scan_bodies():
    def body(c, x):
        return c, x.astype(jnp.float32).sum()

    def f(xs):
        return jax.lax.scan(body, 0.0, xs)

    jaxpr = jax.make_jaxpr(f)(
        jax.ShapeDtypeStruct((4, 512, 512), jnp.bfloat16))
    unit = AuditUnit(name="fixture", kind="fixture", jaxpr=jaxpr,
                     compute_dtype="bfloat16")
    assert _findings(rule_dtype_drift(unit), severity=WARNING)


# ---------------------------------------------------------------------------
# R4 recompilation-hazard
# ---------------------------------------------------------------------------

class _UnstableHash:
    def __hash__(self):
        return id(self)             # deepcopy changes id -> cache miss

    def __eq__(self, other):
        return isinstance(other, _UnstableHash)


def test_unhashable_and_hash_unstable_static_args():
    unit = AuditUnit(name="fixture", kind="fixture",
                     static_args={"cfg": [1, 2, 3]})
    errs = _findings(rule_recompilation_hazard(unit), severity=ERROR)
    assert len(errs) == 1 and "unhashable" in errs[0].message

    unit2 = AuditUnit(name="fixture", kind="fixture",
                      static_args={"cfg": _UnstableHash()})
    errs = _findings(rule_recompilation_hazard(unit2), severity=ERROR)
    assert len(errs) == 1 and "hash-unstable" in errs[0].message

    # frozen hashable config objects pass
    from repro.configs.base import get_config
    unit3 = AuditUnit(name="fixture", kind="fixture",
                      static_args={"cfg": get_config("paper-ffn-4k",
                                                     smoke=True)})
    assert rule_recompilation_hazard(unit3) == []


# ---------------------------------------------------------------------------
# baseline ratchet
# ---------------------------------------------------------------------------

def test_baseline_suppresses_and_reports_stale(tmp_path):
    f1 = Finding("collective-accounting", ERROR, "u", "msg",
                 key="all_reduce@g8")
    f2 = Finding("sharding-hygiene", WARNING, "u", "msg2", key="group3")
    base = Baseline(suppressions={f1.fingerprint: "known",
                                  "dtype-drift:u:gone": "stale entry"})
    active, suppressed, stale = apply_baseline([f1, f2], base)
    assert [f.key for f in active] == ["group3"]
    assert [f.key for f in suppressed] == ["all_reduce@g8"]
    assert stale == ["dtype-drift:u:gone"]

    # run_audit's ok gate looks at ACTIVE errors only
    unit = _unit_from_hlo(_AR_BIG, predicted=[])
    res = run_audit([unit])
    assert not res.ok
    fp = res.findings[0].fingerprint
    res2 = run_audit([unit], baseline=Baseline(suppressions={fp: "ok"}))
    assert res2.ok and len(res2.suppressed) == 1

    # baseline files round-trip; a missing file is an empty baseline
    from repro.analysis.findings import write_baseline
    path = tmp_path / "AUDIT_baseline.json"
    write_baseline([f1], str(path))
    loaded = load_baseline(str(path))
    assert loaded.reason(f1.fingerprint)
    assert load_baseline(str(tmp_path / "nope.json")).suppressions == {}


def test_fingerprints_have_no_volatile_numbers():
    unit = _unit_from_hlo(_AR_BIG, predicted=[])
    for f in run_rules(unit):
        assert "32768" not in f.fingerprint     # 64*512 floats
        assert f.fingerprint.count(":") == 2


def test_report_dict_schema(tmp_path):
    import json
    unit = _unit_from_hlo(_AR_BIG, predicted=[])
    res = run_audit([unit])
    rec = res.as_dict()
    assert rec["schema"] == "audit-report/v1"
    assert rec["ok"] is False
    assert rec["counts"]["error"] == 1
    assert rec["units"][0]["collectives"] == {
        "all_reduce@g8": {"count": 1, "m_floats": 64 * 512.0}}
    out = tmp_path / "AUDIT_report.json"
    res.write(str(out))
    assert json.load(open(out))["schema"] == "audit-report/v1"
