"""Edge cases of the synthetic traffic generator (serve/traffic.py).

The fleet replays 100k+ request traces, so the generator's corner
behaviors — zero-arrival windows inside bursty traces, duplicate
arrival timestamps, ``max_requests`` truncation, seeded determinism —
are load-bearing: the DES event loop, the FCFS group former and the
transfer account's a-priori prediction all consume these traces raw.
"""
import numpy as np
import pytest

from repro.serve.traffic import TRACE_KINDS, TraceItem, make_trace


def _arrivals(trace):
    return [t.arrival_s for t in trace]


class TestSeededDeterminism:
    def test_same_seed_same_trace(self):
        a = make_trace("bursty", n=200, seed=7)
        b = make_trace("bursty", n=200, seed=7)
        assert a == b

    def test_different_seed_different_trace(self):
        a = make_trace("bursty", n=200, seed=7)
        b = make_trace("bursty", n=200, seed=8)
        assert a != b

    @pytest.mark.parametrize("kind", TRACE_KINDS)
    def test_all_kinds_deterministic(self, kind):
        assert make_trace(kind, n=64, seed=3) == \
            make_trace(kind, n=64, seed=3)


class TestZeroArrivalWindows:
    def test_bursty_has_quiet_windows(self):
        """A bursty trace at a modest base rate must contain windows
        with NO arrivals (the quiet phase between bursts) — the fleet's
        scale-down path only ever triggers inside these."""
        trace = make_trace("bursty", n=500, rate_rps=20.0,
                           burst_factor=16.0, seed=0)
        arr = _arrivals(trace)
        span = arr[-1]
        # split the span into 100 windows; at a uniform rate every
        # window would hold ~5 arrivals — bursts concentrate them
        edges = np.linspace(0.0, span, 101)
        counts, _ = np.histogram(arr, bins=edges)
        assert (counts == 0).any(), \
            "bursty trace had no zero-arrival window"

    def test_closed_trace_is_single_window(self):
        trace = make_trace("closed", n=32, seed=1)
        assert all(t.arrival_s == 0.0 for t in trace)

    def test_arrivals_monotonic(self):
        for kind in TRACE_KINDS:
            arr = _arrivals(make_trace(kind, n=128, seed=2))
            assert arr == sorted(arr)


class TestDuplicateArrivals:
    def test_closed_duplicates_all_zero(self):
        """The degenerate all-at-once trace: every arrival duplicates.
        The replay must still admit all of them (one prefill group per
        bucket) — regression for tie-breaking in arrival ordering."""
        trace = make_trace("closed", n=16, seed=5)
        assert len(set(_arrivals(trace))) == 1

    def test_rounding_can_collide_and_replay_survives(self):
        """arrival_s is rounded to 1e-6 s, so a hot burst can collide
        two arrivals onto one timestamp; sort stability over the trace
        order must keep the trace usable as a replay key."""
        trace = [TraceItem(arrival_s=0.5, prompt_len=8,
                           max_new_tokens=4),
                 TraceItem(arrival_s=0.5, prompt_len=16,
                           max_new_tokens=4),
                 TraceItem(arrival_s=0.25, prompt_len=8,
                           max_new_tokens=4)]
        ordered = sorted(trace, key=lambda t: t.arrival_s)
        assert [t.prompt_len for t in ordered] == [8, 8, 16]

    def test_high_rate_burst_duplicates(self):
        """At an extreme burst rate the 1e-6 rounding makes real
        duplicate timestamps; the generator must not dedupe or reorder
        them."""
        trace = make_trace("bursty", n=3000, rate_rps=5e5,
                           burst_factor=10.0, burst_fraction=0.9,
                           seed=11)
        arr = _arrivals(trace)
        assert len(set(arr)) < len(arr), \
            "expected duplicate timestamps at 5e5 rps"
        assert arr == sorted(arr)


class TestMaxRequestsTruncation:
    def test_prefix_property(self):
        """make_trace(n=N, max_requests=M) is EXACTLY the first M items
        of make_trace(n=N): the length arrays are drawn at size n
        before truncation, so capping the trace never changes the
        drawn workload — the property the fleet's trace capping and
        resume rely on."""
        full = make_trace("bursty", n=400, seed=9)
        capped = make_trace("bursty", n=400, max_requests=150, seed=9)
        assert len(capped) == 150
        assert capped == full[:150]

    def test_not_equal_to_smaller_draw(self):
        """...and it is NOT the same as drawing n=M directly (the
        vectorized draws differ) — documents why max_requests exists
        instead of callers just lowering n."""
        capped = make_trace("bursty", n=400, max_requests=150, seed=9)
        small = make_trace("bursty", n=150, seed=9)
        assert capped != small

    def test_cap_beyond_n_is_noop(self):
        full = make_trace("poisson", n=50, seed=4)
        assert make_trace("poisson", n=50, max_requests=500,
                          seed=4) == full

    def test_zero_cap_means_uncapped(self):
        full = make_trace("poisson", n=50, seed=4)
        assert make_trace("poisson", n=50, max_requests=0,
                          seed=4) == full


def test_unknown_kind_rejected():
    with pytest.raises(ValueError, match="unknown trace kind"):
        make_trace("diurnal", n=8)


def test_length_ranges_respected():
    trace = make_trace("poisson", n=300, prompt_len_range=(4, 48),
                       new_tokens_range=(4, 24), seed=6)
    assert all(4 <= t.prompt_len <= 48 for t in trace)
    assert all(4 <= t.max_new_tokens <= 24 for t in trace)
