"""Pipeline parallelism (pp mesh axis + 1F1B wavefront): deterministic
pins for the schedule, the pipelined FFN step, the full-model trainer
path, the stage-boundary energy accounting, and the deprecation shim.

The property-based generalization of the equivalence pins lives in
tests/test_hypothesis.py (same oracle: helpers.assert_pipeline_
equivalence)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import assert_pipeline_equivalence, make_batch, pipeline_cfg
from repro.parallel.axes import MeshAxes, resolve_spec
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# mesh + axes
# ---------------------------------------------------------------------------

def test_pp_mesh_and_axes(mesh222, mesh24):
    axes = MeshAxes.from_mesh(mesh222)
    assert (axes.pp, axes.dp, axes.tp) == (2, 2, 2)
    assert axes.pp_names == ("pipe",)
    assert axes.all_names == ("pipe", "data", "model")
    # 'pp' spec entries bind to the pipe axis, and vanish on pp=1 meshes
    assert resolve_spec(P("pp", None, "tp"), axes) == P("pipe", None,
                                                        "model")
    flat = MeshAxes.from_mesh(mesh24)
    assert flat.pp == 1 and flat.pp_names == ()
    assert resolve_spec(P("pp", None, "tp"), flat) == P(None, None,
                                                        "model")


# ---------------------------------------------------------------------------
# 1F1B schedule (fixed-case pins; invariants are property-tested)
# ---------------------------------------------------------------------------

def test_1f1b_table_pinned():
    from repro.train.pipeline import PipelineSchedule
    sched = PipelineSchedule(stages=3, microbatches=4)
    assert sched.num_ticks == 6
    assert sched.bubble_fraction == pytest.approx(2 / 6)
    # stage 0: two warmup forwards, steady 1F1B, drain
    assert sched.table(0) == [("F", 0), ("F", 1), ("F", 2), ("B", 0),
                              ("F", 3), ("B", 1), ("B", 2), ("B", 3)]
    # last stage: strict alternation from the start
    assert sched.table(2) == [("F", 0), ("B", 0), ("F", 1), ("B", 1),
                              ("F", 2), ("B", 2), ("F", 3), ("B", 3)]
    assert [sched.max_in_flight(s) for s in range(3)] == [3, 2, 1]
    assert sched.stage_bounds(8) == [(0, 3), (3, 6), (6, 8)]


def test_p2p_pricing_single_hop():
    from repro.core.energy import (PAPER_COLLECTIVE_FITS, comm_time_us,
                                   pipeline_p2p_time_us)
    from repro.train.pipeline import PipelineSchedule
    c1, c2 = PAPER_COLLECTIVE_FITS["collective_permute"]
    assert comm_time_us("collective_permute", 1000.0, 2) \
        == pytest.approx(c1 + c2 * 1000.0)
    # single hop: latency does not scale with the stage count
    assert comm_time_us("collective_permute", 1000.0, 8) \
        == comm_time_us("collective_permute", 1000.0, 2)
    sched = PipelineSchedule(stages=2, microbatches=4)
    ideal = pipeline_p2p_time_us(sched, 1000.0)
    spmd = pipeline_p2p_time_us(sched, 1000.0, executed=True)
    assert ideal == pytest.approx(8 * (c1 + c2 * 1000.0))
    assert spmd == pytest.approx(8 * (c1 + c2 * 1000.0))  # 2*(T-1), T=5
    assert pipeline_p2p_time_us(PipelineSchedule(1, 4), 1000.0) == 0.0


def test_phantom_costs_rename_keeps_deprecated_alias():
    from repro.core import energy
    ref = energy.phantom_costs(512, 4, 2, 8, 32, energy.TPU_PEAK_FLOPS)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old = energy.pp_costs(512, 4, 2, 8, 32, energy.TPU_PEAK_FLOPS)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert old == ref


# ---------------------------------------------------------------------------
# pipelined FFN step: fixed-case equivalence + structure errors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,k,M,stages",
                         [("tensor", 2, 2, 2),
                          ("phantom", 4, 4, 2),
                          ("mixed", 2, 1, 4)])
def test_ffn_pipeline_matches_reference(compiled_step_cache, mesh222,
                                        mesh124, mesh12, kind, k, M,
                                        stages):
    mesh_pp = mesh222 if stages == 2 else mesh124
    assert_pipeline_equivalence(compiled_step_cache, mesh_pp, mesh12,
                                kind, k, M, stages, seed=3)


def test_staged_config_equals_plain_stack(compiled_step_cache, mesh12):
    """A homogeneous S-stage config IS the plain L-layer model: mapping
    the [S, L/S, ...] stage stack onto the flat [L, ...] stack gives
    bit-comparable losses."""
    from repro.core.ffn import make_ffn_train_step
    from repro.data.synthetic import TeacherDataset
    from repro.optim import SGD
    from repro.parallel.params import materialize

    cfg_staged = pipeline_cfg("tensor", 2, 2, 2, layers=4)
    cfg_plain = cfg_staged.replace(
        pipeline=type(cfg_staged.pipeline)(), microbatches=1,
        name="pipe-plain")
    opt = SGD(0.2)
    step_s, decls_s, _ = compiled_step_cache.build(
        lambda c, m, b: make_ffn_train_step(c, m, opt, b),
        cfg_staged, mesh12, 16)
    step_p, decls_p, _ = compiled_step_cache.build(
        lambda c, m, b: make_ffn_train_step(c, m, opt, b),
        cfg_plain, mesh12, 16)
    params_s = materialize(decls_s, 11)
    params_p = {"layers": jax.tree.map(
        lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]),
        params_s["stages"])}
    o_s, o_p = opt.init(params_s), opt.init(params_p)
    ds = TeacherDataset(cfg_staged.ffn_width, 16, seed=2)
    for s in range(3):
        x, y = ds(s)
        params_s, o_s, loss_s = step_s(params_s, o_s, jnp.int32(s), x, y)
        params_p, o_p, loss_p = step_p(params_p, o_p, jnp.int32(s), x, y)
        np.testing.assert_allclose(float(loss_s), float(loss_p),
                                   rtol=2e-4)


def test_pipeline_structure_errors(mesh222):
    from repro.core.ffn import ffn_decls, make_ffn_train_step
    from repro.optim import SGD
    axes = MeshAxes.from_mesh(mesh222)
    # pipe mesh with a single-stage config
    with pytest.raises(ValueError, match="pipe axis"):
        make_ffn_train_step(pipeline_cfg("tensor", 2, 1, 1), mesh222,
                            SGD(0.1), 8)
    # layer count must divide into stages
    with pytest.raises(ValueError, match="divide"):
        ffn_decls(pipeline_cfg("tensor", 2, 1, 2, layers=3), axes)
    # stage count fixed by the mesh
    with pytest.raises(ValueError, match="pipe axis"):
        make_ffn_train_step(pipeline_cfg("tensor", 2, 1, 4), mesh222,
                            SGD(0.1), 8)


# ---------------------------------------------------------------------------
# executed-SPMD prediction matches the lowered step (ledger join)
# ---------------------------------------------------------------------------

def test_pipeline_probe_boundary_join(mesh222):
    from repro.telemetry import (measure_ffn_pipeline_step,
                                 pipeline_ffn_step_prediction)
    cfg = pipeline_cfg("phantom", 4, 2, 2, n=64, layers=2)
    measured, predicted = measure_ffn_pipeline_step(cfg, mesh222, 16)
    rb = (measured["boundary_wire_bytes_per_device"]
          / predicted["boundary_wire_bytes_per_device"])
    rw = (measured["collective_wire_bytes_per_device"]
          / predicted["collective_wire_bytes_per_device"])
    assert 0.99 <= rb <= 1.01, (measured, predicted)
    assert 0.95 <= rw <= 1.05
    # ideal (deployment) vs executed-SPMD boundary accounts: 2M vs
    # 2(M + pp - 2) events — these coincide exactly at pp=2, and the
    # executed account is what the lowered HLO must match
    ideal = pipeline_ffn_step_prediction(cfg, 2, 2, 2, 16, executed=False)
    assert ideal["boundary_wire_bytes_per_device"] \
        == predicted["boundary_wire_bytes_per_device"]
    from repro.train.pipeline import PipelineSchedule
    deep = PipelineSchedule(stages=4, microbatches=2)
    assert len(deep.p2p_events(1.0, executed=True)) \
        > len(deep.p2p_events(1.0))
    assert predicted["ticks"] == 3 and ideal["bubble_fraction"] \
        == pytest.approx(1 / 3)


# ---------------------------------------------------------------------------
# full-model 1F1B (trainer path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["phantom"])
def test_full_model_pipeline_matches_flat_trainer(mesh222, mesh42, impl):
    """make_train_step on the pp mesh trains the SAME model as the flat
    dp×tp mesh: identical params (reshape-consistent init), matching
    loss and grad norm step for step.  Parametrized on the phantom
    config only (fp residual layout — the harder boundary carry; the
    dense trainer path is pinned end-to-end by `launch.train --pp`,
    whose loss matches pp=1, and its blocks run here too via the dense
    attention/embed/head sites) to keep the suite inside the CI
    wall-time budget."""
    import dataclasses
    from repro.configs.base import ModelConfig, PhantomConfig
    from repro.optim import SGD
    from repro.train.trainer import make_train_step

    cfg = ModelConfig(
        name=f"pipe-lm-{impl}", family="dense", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64, mlp="gelu",
        rope="full", ffn_impl=impl, phantom=PhantomConfig(k=2),
        remat="none", dtype="float32")
    B, S = 8, 16
    batch = make_batch(cfg, B, S, seed=0)

    losses = {}
    for name, mesh in (("pp", mesh222), ("flat", mesh42)):
        step_fn, decls, _ = make_train_step(cfg, mesh, SGD(0.1),
                                            microbatches=2)
        from repro.parallel.params import materialize
        params = materialize(decls, 5)
        opt_state = SGD(0.1).init(params)
        ms = []
        for s in range(2):
            params, opt_state, m = step_fn(params, opt_state,
                                           jnp.int32(s), batch)
            ms.append((float(m["loss"]), float(m["grad_norm"])))
        losses[name] = ms
    for (l_pp, g_pp), (l_fl, g_fl) in zip(losses["pp"], losses["flat"]):
        np.testing.assert_allclose(l_pp, l_fl, rtol=2e-3)
        np.testing.assert_allclose(g_pp, g_fl, rtol=5e-3)
