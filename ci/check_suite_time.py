#!/usr/bin/env python3
"""Guard the tier-1 suite's wall time against regressions.

Usage: check_suite_time.py <measured_seconds_file> <baseline_file>

The baseline file holds the pre-PR wall seconds (first token; the rest
of the line is free-form provenance).  The run fails when the measured
time exceeds baseline * 1.25 — the budget test-suite satellites must
stay inside.  Override the factor with SUITE_TIME_FACTOR when a CI
runner class changes.
"""
import os
import sys


def main() -> int:
    measured = float(open(sys.argv[1]).read().strip())
    baseline = float(open(sys.argv[2]).read().split()[0])
    factor = float(os.environ.get("SUITE_TIME_FACTOR", "1.25"))
    limit = baseline * factor
    print(f"tier-1 wall time: {measured:.0f}s "
          f"(baseline {baseline:.0f}s, limit {limit:.0f}s = "
          f"baseline x {factor})")
    if measured > limit:
        print(f"FAIL: suite regressed "
              f"{measured / baseline - 1.0:+.0%} over the recorded "
              f"baseline; speed the tests up or re-baseline "
              f"ci/tier1_baseline.txt with justification",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
