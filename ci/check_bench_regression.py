#!/usr/bin/env python3
"""Band the benchmark report against a checked-in baseline.

Usage:
  python ci/check_bench_regression.py [--report BENCH_report.json]
      [--baseline ci/bench_baseline.json] [--update-baseline]

Two guards over a fresh ``BENCH_report.json``:

* **suite seconds** — each suite's wall time must stay under
  ``max(baseline, BENCH_SECONDS_FLOOR) * BENCH_SECONDS_FACTOR``
  (defaults 1.0 s and 2.5: cross-machine wall clocks are noisy, and
  the analytic suites finish in milliseconds where a multiplicative
  band alone would trip on scheduler jitter).
* **measured/predicted ratios** — every joined entry's ratio, keyed
  ``entry_name/ratio_key``, must stay inside
  ``[baseline / BENCH_RATIO_FACTOR, baseline * BENCH_RATIO_FACTOR]``
  (default 1.5).  A drifting ratio means the energy model and the
  measurement disagree in a new way — exactly the regression the
  ledger exists to catch.

Only keys present in BOTH views are compared (new suites/entries are
reported, not failed); a suite marked failed in the report always
fails the check.  ``--update-baseline`` rewrites the baseline from the
report — do that deliberately, with the cause in the commit message.
"""
import argparse
import json
import os
import sys

SCHEMA = "bench-baseline/v1"
HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
DEFAULT_REPORT = os.path.join(ROOT, "BENCH_report.json")
DEFAULT_BASELINE = os.path.join(HERE, "bench_baseline.json")


def extract(report: dict) -> dict:
    """The comparable view of a BENCH_report.json."""
    suites = {name: float(rec.get("seconds", 0.0))
              for name, rec in (report.get("suites") or {}).items()
              if rec.get("status") == "ok"}
    ratios = {}
    for e in report.get("entries", []):
        for key, val in (e.get("ratios") or {}).items():
            ratios[f"{e['name']}/{key}"] = float(val)
    return {"schema": SCHEMA, "suites": suites, "ratios": ratios}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--report", default=DEFAULT_REPORT)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the report")
    args = ap.parse_args(argv)

    with open(args.report) as f:
        report = json.load(f)
    got = extract(report)

    if args.update_baseline:
        with open(args.baseline, "w") as f:
            json.dump(got, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.baseline}: {len(got['suites'])} suites, "
              f"{len(got['ratios'])} ratios")
        return 0

    with open(args.baseline) as f:
        base = json.load(f)
    if base.get("schema") != SCHEMA:
        print(f"{args.baseline}: unknown schema {base.get('schema')!r}",
              file=sys.stderr)
        return 2

    sec_factor = float(os.environ.get("BENCH_SECONDS_FACTOR", "2.5"))
    sec_floor = float(os.environ.get("BENCH_SECONDS_FLOOR", "1.0"))
    ratio_factor = float(os.environ.get("BENCH_RATIO_FACTOR", "1.5"))
    failures = []

    bad = {name: rec for name, rec in
           (report.get("suites") or {}).items()
           if rec.get("status") != "ok"}
    for name, rec in sorted(bad.items()):
        failures.append(f"suite {name} status={rec.get('status')}: "
                        f"{rec.get('error', '')}")

    base_suites = base.get("suites") or {}
    common = sorted(set(base_suites) & set(got["suites"]))
    for name in common:
        b, g = base_suites[name], got["suites"][name]
        limit = max(b, sec_floor) * sec_factor
        mark = "FAIL" if g > limit else "ok"
        print(f"suite {name:<16} {g:8.3f}s  (baseline {b:.3f}s, "
              f"limit {limit:.3f}s) {mark}")
        if g > limit:
            failures.append(f"suite {name} wall {g:.3f}s > "
                            f"limit {limit:.3f}s")
    for name in sorted(set(got["suites"]) - set(base_suites)):
        print(f"suite {name:<16} {got['suites'][name]:8.3f}s  "
              f"(no baseline — run --update-baseline)")

    base_ratios = base.get("ratios") or {}
    common_r = sorted(set(base_ratios) & set(got["ratios"]))
    n_ok = 0
    for key in common_r:
        b, g = base_ratios[key], got["ratios"][key]
        lo, hi = b / ratio_factor, b * ratio_factor
        if not (lo <= g <= hi):
            failures.append(f"ratio {key} = {g:.4f} outside "
                            f"[{lo:.4f}, {hi:.4f}] "
                            f"(baseline {b:.4f} x{ratio_factor})")
            print(f"ratio {key} = {g:.4f} vs baseline {b:.4f} FAIL")
        else:
            n_ok += 1
    print(f"ratios: {n_ok}/{len(common_r)} within x{ratio_factor} "
          f"of baseline "
          f"({len(set(got['ratios']) - set(base_ratios))} new, "
          f"{len(set(base_ratios) - set(got['ratios']))} absent)")

    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    print("bench regression check: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
